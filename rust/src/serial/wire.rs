//! EdgeFrame — the transport envelope every among-device connection uses
//! (mqttsink/src, zmqsink/src, query elements, NNStreamer-Edge analog).
//!
//! Carries the buffer payload plus everything a *remote* pipeline needs to
//! reconstruct the stream: the caps string (so the receiver negotiates
//! without out-of-band schema — §4.2.1), timestamps + publisher base-time
//! (§4.2.3 sync), query routing ids (§4.2.2), and the compression codec.
//!
//! Layout (little-endian):
//! ```text
//! "EPEF" | ver u8 | flags u8 | codec u8 | pad u8
//! pts u64 | duration u64 | base_universal u64 | client_id u64 | seq u64 | capture_universal u64
//! caps_len u32 | caps utf8 | payload_len u32 | payload (possibly compressed)
//! ```
//! `u64::MAX` encodes "absent" for the optional u64 fields.
//!
//! ## Zero-copy data path
//!
//! The hot path never assembles a contiguous frame:
//!
//! - [`encode_vectored`] returns a [`WireFrame`] — a small header `Bytes`
//!   (fixed fields + caps + payload length) and the buffer's payload
//!   `Bytes` shared as-is (`Codec::None` adds **zero** payload copies).
//!   For `Codec::Zlib` the streaming compressor deflates directly onto
//!   the header being assembled, so the whole compressed frame is ONE
//!   allocation with header/payload as two views into it.
//! - [`encode_vectored_auto`] is the per-link adaptive variant backing
//!   `Codec::Auto` (skips deflate on streams that sample incompressible).
//! - [`write_frame_vectored`] / [`WireFrame::write_to`] emit both parts
//!   with one scatter-gather write.
//! - [`read_frame`] performs the hop's single allocation (one `Bytes` per
//!   received frame) and [`decode_shared`] returns a `Buffer` whose
//!   payload is a slice *view* into that allocation (`Codec::None`), or
//!   streams the inflater straight out of the frame view into one fresh
//!   allocation (`Codec::Zlib`) — ≤ 2 payload allocations per compressed
//!   hop, with the decompressed-size guard enforced mid-stream.
//!
//! The contiguous [`encode`]/[`decode`] entry points remain for
//! borrowed-slice callers and tests; their copies are counted by
//! [`crate::buffer::bytes`].

use crate::buffer::{Buffer, Bytes, Meta};
use crate::caps::Caps;
use crate::serial::compress::{self, AutoCodec, AutoDecision, Codec, MAX_DECOMPRESSED};
use crate::serial::delta::{self, DeltaChain, DEFAULT_KEYFRAME_INTERVAL};
use crate::tensor::{sparse, Format, TensorsInfo};
use crate::util::{read_u32, read_u64, write_all_vectored, Error, Result};

pub const WIRE_MAGIC: &[u8; 4] = b"EPEF";
const VERSION: u8 = 1;
const FIXED: usize = 8 + 6 * 8;
const ABSENT: u64 = u64::MAX;

/// Header flags-byte bit: this `Codec::Delta` frame is a keyframe (a
/// plain full-frame deflate that re-keys the receiver's chain).
pub const FLAG_KEYFRAME: u8 = 0x01;

pub use crate::serial::delta::DEFAULT_KEYFRAME_INTERVAL;

/// An encoded EdgeFrame as two independently shareable parts: everything
/// before the payload, and the payload itself. Cloning is O(1); the same
/// frame can be fanned out to N subscribers without duplication.
#[derive(Debug, Clone)]
pub struct WireFrame {
    /// Fixed fields + caps string + payload-length prefix.
    pub header: Bytes,
    /// Payload bytes — for `Codec::None` this *is* the buffer's payload.
    pub payload: Bytes,
}

impl WireFrame {
    /// Total encoded length (header + payload).
    pub fn len(&self) -> usize {
        self.header.len() + self.payload.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Assemble into one contiguous `Vec` (counted copy; compat/tests).
    pub fn to_vec(&self) -> Vec<u8> {
        crate::buffer::record_copy(self.len());
        let mut out = Vec::with_capacity(self.len());
        out.extend_from_slice(&self.header);
        out.extend_from_slice(&self.payload);
        out
    }

    /// Write header + payload with one vectored call (no assembly copy).
    pub fn write_to<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        write_all_vectored(w, &[self.header.as_slice(), self.payload.as_slice()])
    }
}

/// Append everything of an EdgeFrame header that precedes the
/// payload-length field. `codec` must already be resolved to a concrete
/// arm; `Auto` is a policy and never reaches the wire. `flags` carries
/// [`FLAG_KEYFRAME`] and `chain_seq` the wrapping delta-chain sequence
/// (both 0 for non-delta codecs).
fn push_header_fields(
    out: &mut Vec<u8>,
    buf: &Buffer,
    caps_str: &str,
    codec: Codec,
    flags: u8,
    chain_seq: u8,
) {
    debug_assert!(codec != Codec::Auto, "Codec::Auto must be resolved before encoding");
    out.extend_from_slice(WIRE_MAGIC);
    out.push(VERSION);
    out.push(flags);
    out.push(codec as u8);
    out.push(chain_seq);
    for v in [
        buf.pts.unwrap_or(ABSENT),
        buf.duration.unwrap_or(ABSENT),
        buf.meta.remote_base_universal.unwrap_or(ABSENT),
        buf.meta.client_id.unwrap_or(ABSENT),
        buf.meta.seq.unwrap_or(ABSENT),
        buf.meta.capture_universal.unwrap_or(ABSENT),
    ] {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(&(caps_str.len() as u32).to_le_bytes());
    out.extend_from_slice(caps_str.as_bytes());
}

/// Pass-through frame: header in its own small allocation, payload shared
/// from the buffer as-is (zero payload copies).
fn encode_none(buf: &Buffer, caps_str: &str) -> WireFrame {
    let mut header = Vec::with_capacity(FIXED + caps_str.len() + 8);
    push_header_fields(&mut header, buf, caps_str, Codec::None, 0, 0);
    header.extend_from_slice(&(buf.data.len() as u32).to_le_bytes());
    WireFrame { header: Bytes::from(header), payload: buf.data.clone() }
}

/// Freeze an assembled frame `Vec` into a [`WireFrame`] after patching
/// the payload-length field (`n` payload bytes starting at
/// `payload_start`): header and payload become two views into the one
/// backing allocation.
fn seal_frame(mut frame: Vec<u8>, payload_start: usize, n: usize) -> Result<WireFrame> {
    if n > u32::MAX as usize {
        return Err(Error::Serial(format!("encoded payload {n} exceeds u32 framing")));
    }
    frame[payload_start - 4..payload_start].copy_from_slice(&(n as u32).to_le_bytes());
    let all = Bytes::from(frame);
    Ok(WireFrame { header: all.slice(..payload_start), payload: all.slice(payload_start..) })
}

/// Compressed frame as ONE allocation: the streaming compressor deflates
/// the payload directly onto the tail of the header being assembled, the
/// payload-length field is patched in afterwards, and header/payload are
/// returned as two views into that single backing buffer.
fn encode_zlib(buf: &Buffer, caps_str: &str) -> Result<WireFrame> {
    let mut frame = Vec::with_capacity(FIXED + caps_str.len() + 8 + buf.data.len() / 2 + 64);
    push_header_fields(&mut frame, buf, caps_str, Codec::Zlib, 0, 0);
    frame.extend_from_slice(&0u32.to_le_bytes()); // payload_len, patched below
    let payload_start = frame.len();
    let n = compress::deflate_into(&mut frame, &buf.data)?;
    seal_frame(frame, payload_start, n)
}

/// Delta-codec frame, same one-allocation shape as [`encode_zlib`]:
/// keyframes (`prev == None`) deflate the full payload; delta frames
/// stream the XOR residue against `prev` into the compressor.
fn encode_delta_frame(
    buf: &Buffer,
    caps_str: &str,
    flags: u8,
    chain_seq: u8,
    prev: Option<&[u8]>,
) -> Result<WireFrame> {
    let mut frame = Vec::with_capacity(FIXED + caps_str.len() + 8 + buf.data.len() / 2 + 64);
    push_header_fields(&mut frame, buf, caps_str, Codec::Delta, flags, chain_seq);
    frame.extend_from_slice(&0u32.to_le_bytes()); // payload_len, patched below
    let payload_start = frame.len();
    let n = match prev {
        None => compress::deflate_into(&mut frame, &buf.data)?,
        Some(prev) => delta::xor_deflate_into(&mut frame, &buf.data, prev)?,
    };
    seal_frame(frame, payload_start, n)
}

/// Sparse-codec frame: the payload is each tensor of the (static) frame
/// re-encoded as COO, concatenated — appended straight onto the frame
/// being assembled (one allocation, no per-tensor buffers).
fn encode_sparse_frame(buf: &Buffer, caps_str: &str, info: &TensorsInfo) -> Result<WireFrame> {
    let mut frame = Vec::with_capacity(FIXED + caps_str.len() + 8 + buf.data.len() / 2 + 64);
    push_header_fields(&mut frame, buf, caps_str, Codec::Sparse, 0, 0);
    frame.extend_from_slice(&0u32.to_le_bytes()); // payload_len, patched below
    let payload_start = frame.len();
    let mut off = 0;
    for t in &info.tensors {
        let sz = t.size();
        sparse::encode_into(t, &buf.data[off..off + sz], &mut frame)?;
        off += sz;
    }
    let n = frame.len() - payload_start;
    seal_frame(frame, payload_start, n)
}

/// Predicted sparse-codec payload size for a dense tensors frame (an
/// nnz-counting scan per tensor; no encoding happens).
fn sparse_payload_size(info: &TensorsInfo, data: &[u8]) -> usize {
    let mut total = 0;
    let mut off = 0;
    for t in &info.tensors {
        let sz = t.size();
        total += sparse::encoded_size(t, sparse::count_nnz(t, &data[off..off + sz]));
        off += sz;
    }
    total
}

/// Encode a buffer (+ its caps) into a [`WireFrame`] without copying the
/// payload when `codec == Codec::None`, and without an intermediate
/// compressed buffer when `codec == Codec::Zlib`.
///
/// `Codec::Auto` here is resolved statelessly: the frame is deflated once
/// and kept only if compression actually shrank the payload. Links that
/// encode many frames should hold an [`AutoCodec`] and use
/// [`encode_vectored_auto`] instead, which learns to skip deflate
/// entirely on incompressible streams.
pub fn encode_vectored(buf: &Buffer, caps: Option<&Caps>, codec: Codec) -> Result<WireFrame> {
    let caps_str = caps.map(|c| c.to_string()).unwrap_or_default();
    match codec {
        Codec::None => Ok(encode_none(buf, &caps_str)),
        Codec::Zlib => encode_zlib(buf, &caps_str),
        Codec::Auto => {
            let f = encode_zlib(buf, &caps_str)?;
            if f.payload.len() < buf.data.len() {
                Ok(f)
            } else {
                Ok(encode_none(buf, &caps_str))
            }
        }
        Codec::Delta | Codec::Sparse => Err(Error::Serial(format!(
            "Codec::{codec:?} needs per-link state; encode through wire::LinkCodec"
        ))),
    }
}

/// Adaptive encode for a long-lived link: `auto` decides per frame
/// whether deflate is worth paying for (sampling achieved ratios and
/// recording decisions in per-link metrics), and a compressed frame that
/// fails to shrink the payload is demoted to `Codec::None` on the wire.
pub fn encode_vectored_auto(
    buf: &Buffer,
    caps: Option<&Caps>,
    auto: &mut AutoCodec,
) -> Result<WireFrame> {
    let caps_str = caps.map(|c| c.to_string()).unwrap_or_default();
    match auto.next_codec() {
        Codec::Zlib => {
            let f = encode_zlib(buf, &caps_str)?;
            auto.record_zlib(buf.data.len(), f.payload.len());
            if f.payload.len() < buf.data.len() {
                Ok(f)
            } else {
                Ok(encode_none(buf, &caps_str))
            }
        }
        _ => {
            auto.record_none();
            Ok(encode_none(buf, &caps_str))
        }
    }
}

/// Encode into one contiguous `Vec` (compat; copies the payload once).
pub fn encode(buf: &Buffer, caps: Option<&Caps>, codec: Codec) -> Result<Vec<u8>> {
    Ok(encode_vectored(buf, caps, codec)?.to_vec())
}

/// Encode-side delta metric handles, resolved once per link.
struct DeltaMetrics {
    keyframes: std::sync::Arc<crate::metrics::Counter>,
    deltas: std::sync::Arc<crate::metrics::Counter>,
    bytes_saved: std::sync::Arc<crate::metrics::Counter>,
}

impl DeltaMetrics {
    fn new(link: &str) -> Self {
        let m = crate::metrics::global();
        Self {
            keyframes: m.counter(&format!("codec.delta.{link}.keyframes")),
            deltas: m.counter(&format!("codec.delta.{link}.deltas")),
            bytes_saved: m.counter(&format!("codec.delta.{link}.bytes_saved")),
        }
    }
}

/// Per-link encode state: the configured codec, the adaptive sampler
/// backing `Codec::Auto`, the previous payload + delta chain backing
/// `Codec::Delta`, and the cached tensor layout backing `Codec::Sparse`.
/// Transport elements hold one of these per link so they all share a
/// single dispatch (and a single place to evolve the codec policy)
/// instead of each re-implementing it.
pub struct LinkCodec {
    codec: Codec,
    auto: Option<AutoCodec>,
    chain: DeltaChain,
    /// Previous payload sent on this link (O(1) `Bytes` clone), kept
    /// for every codec so `Auto` can sample the delta arm at any time.
    prev: Option<Bytes>,
    cached_caps: Option<Caps>,
    cached_info: Option<TensorsInfo>,
    dm: Option<DeltaMetrics>,
}

impl LinkCodec {
    /// `link` names the per-link metrics scope (`codec.auto.<link>.*`,
    /// `codec.delta.<link>.*`); it is only consulted for the stateful
    /// codecs (`Auto`/`Delta`).
    pub fn new(codec: Codec, link: &str) -> Self {
        Self {
            codec,
            auto: (codec == Codec::Auto).then(|| AutoCodec::new(link)),
            chain: DeltaChain::new(DEFAULT_KEYFRAME_INTERVAL),
            prev: None,
            cached_caps: None,
            cached_info: None,
            dm: (!link.is_empty() && matches!(codec, Codec::Delta | Codec::Auto))
                .then(|| DeltaMetrics::new(link)),
        }
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Frames per keyframe period for the delta arm (builder form).
    pub fn with_keyframe_interval(mut self, interval: u64) -> Self {
        self.set_keyframe_interval(interval);
        self
    }

    pub fn set_keyframe_interval(&mut self, interval: u64) {
        self.chain.set_interval(interval);
    }

    pub fn keyframe_interval(&self) -> u64 {
        self.chain.interval()
    }

    /// Drop the link's frame history (reconnect / failover / re-route):
    /// the receiver's state is gone or belongs to someone else, so the
    /// next delta-codec frame must be a keyframe.
    pub fn reset_chain(&mut self) {
        self.chain.invalidate();
        self.prev = None;
    }

    /// Encode one frame with this link's codec (adaptive for `Auto`,
    /// stateful for `Delta`, layout-aware for `Sparse`).
    pub fn encode(&mut self, buf: &Buffer, caps: Option<&Caps>) -> Result<WireFrame> {
        let f = self.encode_inner(buf, caps)?;
        self.prev = Some(buf.data.clone());
        Ok(f)
    }

    fn encode_inner(&mut self, buf: &Buffer, caps: Option<&Caps>) -> Result<WireFrame> {
        match self.codec {
            Codec::None | Codec::Zlib => {
                self.chain.invalidate();
                encode_vectored(buf, caps, self.codec)
            }
            Codec::Delta => {
                let caps_str = caps.map(|c| c.to_string()).unwrap_or_default();
                self.emit_delta(buf, &caps_str)
            }
            Codec::Sparse => {
                self.chain.invalidate();
                self.refresh_tensor_cache(caps);
                let caps_str = caps.map(|c| c.to_string()).unwrap_or_default();
                match self.sparse_applicable(buf.data.len()) {
                    // Explicit Sparse still checks that COO pays for
                    // *this* frame (density drifts); dense frames fall
                    // back to plain zlib rather than growing on the wire.
                    Some(info) if sparse_payload_size(info, &buf.data) < buf.data.len() => {
                        encode_sparse_frame(buf, &caps_str, info)
                    }
                    _ => encode_zlib(buf, &caps_str),
                }
            }
            Codec::Auto => self.encode_auto(buf, caps),
        }
    }

    /// The cached tensors layout when the stream is static tensors and
    /// the payload length matches the frame size.
    fn sparse_applicable(&self, payload_len: usize) -> Option<&TensorsInfo> {
        self.cached_info
            .as_ref()
            .filter(|info| payload_len > 0 && info.frame_size() == payload_len)
    }

    fn refresh_tensor_cache(&mut self, caps: Option<&Caps>) {
        match caps {
            Some(c) => {
                if self.cached_caps.as_ref() != Some(c) {
                    self.cached_caps = Some(c.clone());
                    // Only static tensors have a dense payload to scan;
                    // flexible frames carry their own schema and sparse
                    // streams are already COO.
                    self.cached_info = (c.is_tensors()
                        && c.tensor_format().ok() == Some(Format::Static))
                    .then(|| c.tensors_info().ok())
                    .flatten();
                }
            }
            None => {
                self.cached_caps = None;
                self.cached_info = None;
            }
        }
    }

    fn emit_delta(&mut self, buf: &Buffer, caps_str: &str) -> Result<WireFrame> {
        let prev_len = self.prev.as_ref().map(|p| p.len());
        if self.chain.needs_keyframe(prev_len, buf.data.len()) {
            let seq = self.chain.on_keyframe();
            let f = encode_delta_frame(buf, caps_str, FLAG_KEYFRAME, seq, None)?;
            if let Some(dm) = &self.dm {
                dm.keyframes.inc();
            }
            Ok(f)
        } else {
            let prev = self.prev.clone().expect("needs_keyframe is false, so prev exists");
            let seq = self.chain.on_delta();
            let f = encode_delta_frame(buf, caps_str, 0, seq, Some(&prev))?;
            if let Some(dm) = &self.dm {
                dm.deltas.inc();
                dm.bytes_saved.add(buf.data.len().saturating_sub(f.payload.len()) as u64);
            }
            Ok(f)
        }
    }

    fn encode_auto(&mut self, buf: &Buffer, caps: Option<&Caps>) -> Result<WireFrame> {
        self.refresh_tensor_cache(caps);
        let caps_str = caps.map(|c| c.to_string()).unwrap_or_default();
        let raw = buf.data.len();
        let decision = self.auto.as_mut().expect("Auto links hold a sampler").next_mode();
        match decision {
            AutoDecision::Probe => self.probe_auto(buf, &caps_str, raw),
            AutoDecision::Use(Codec::Delta) => {
                let f = self.emit_delta(buf, &caps_str)?;
                self.auto.as_mut().unwrap().record_arm(Codec::Delta, raw, f.payload.len());
                Ok(f)
            }
            AutoDecision::Use(Codec::Sparse) => {
                self.chain.invalidate();
                if self.sparse_applicable(raw).is_some() {
                    let f = {
                        let info = self.sparse_applicable(raw).unwrap();
                        encode_sparse_frame(buf, &caps_str, info)?
                    };
                    self.auto.as_mut().unwrap().record_arm(Codec::Sparse, raw, f.payload.len());
                    Ok(f)
                } else {
                    // Stream stopped being sparse-encodable (caps
                    // changed): fall back to zlib until the next probe.
                    let f = encode_zlib(buf, &caps_str)?;
                    self.auto.as_mut().unwrap().record_arm(Codec::Zlib, raw, f.payload.len());
                    if f.payload.len() < raw {
                        Ok(f)
                    } else {
                        Ok(encode_none(buf, &caps_str))
                    }
                }
            }
            AutoDecision::Use(Codec::Zlib) => {
                self.chain.invalidate();
                let f = encode_zlib(buf, &caps_str)?;
                self.auto.as_mut().unwrap().record_arm(Codec::Zlib, raw, f.payload.len());
                if f.payload.len() < raw {
                    Ok(f)
                } else {
                    Ok(encode_none(buf, &caps_str))
                }
            }
            AutoDecision::Use(_) => {
                self.chain.invalidate();
                self.auto.as_mut().unwrap().record_none();
                Ok(encode_none(buf, &caps_str))
            }
        }
    }

    /// Probe frame: sample every applicable arm's encoded size — zlib is
    /// actually deflated (onto the frame we may emit), delta deflates
    /// the XOR residue into scratch when the previous frame lines up,
    /// sparse is predicted from an nnz scan — then adopt the winner. The
    /// emitted frame is still one allocation: a delta win re-labels the
    /// already-deflated full frame as a keyframe in place (a keyframe
    /// *is* a full-frame deflate).
    fn probe_auto(&mut self, buf: &Buffer, caps_str: &str, raw: usize) -> Result<WireFrame> {
        let mut frame = Vec::with_capacity(FIXED + caps_str.len() + 8 + raw / 2 + 64);
        push_header_fields(&mut frame, buf, caps_str, Codec::Zlib, 0, 0);
        frame.extend_from_slice(&0u32.to_le_bytes());
        let payload_start = frame.len();
        let zlib_n = compress::deflate_into(&mut frame, &buf.data)?;
        let mut candidates = vec![(Codec::Zlib, zlib_n)];
        if raw > 0 && self.prev.as_ref().map(|p| p.len()) == Some(raw) {
            let prev = self.prev.clone().unwrap();
            let mut scratch = Vec::new();
            candidates.push((Codec::Delta, delta::xor_deflate_into(&mut scratch, &buf.data, &prev)?));
        }
        if let Some(info) = self.sparse_applicable(raw) {
            candidates.push((Codec::Sparse, sparse_payload_size(info, &buf.data)));
        }
        let winner = self.auto.as_mut().unwrap().record_probe(raw, &candidates);
        match winner {
            Codec::Delta => {
                // Adopt delta and seed the receiver's chain now: patch
                // the codec/flags/seq bytes of the deflated full frame
                // into a keyframe before freezing it.
                let seq = self.chain.on_keyframe();
                frame[5] = FLAG_KEYFRAME;
                frame[6] = Codec::Delta as u8;
                frame[7] = seq;
                if let Some(dm) = &self.dm {
                    dm.keyframes.inc();
                }
                seal_frame(frame, payload_start, zlib_n)
            }
            Codec::Zlib => {
                self.chain.invalidate();
                seal_frame(frame, payload_start, zlib_n)
            }
            Codec::Sparse => {
                self.chain.invalidate();
                let info = self.sparse_applicable(raw).expect("probed sparse candidate");
                encode_sparse_frame(buf, caps_str, info)
            }
            _ => {
                self.chain.invalidate();
                Ok(encode_none(buf, caps_str))
            }
        }
    }
}

fn codec_from_wire(b: u8) -> Result<Codec> {
    Ok(match b {
        0 => Codec::None,
        1 => Codec::Zlib,
        // 2 (Auto) is a policy discriminant and never travels.
        3 => Codec::Delta,
        4 => Codec::Sparse,
        other => return Err(Error::Serial(format!("unknown wire codec {other}"))),
    })
}

fn opt(v: u64) -> Option<u64> {
    if v == ABSENT {
        None
    } else {
        Some(v)
    }
}

/// Header fields parsed out of a frame, with the payload's byte range.
struct ParsedHeader {
    codec: Codec,
    /// [`FLAG_KEYFRAME`] et al (meaningful for `Codec::Delta`).
    flags: u8,
    /// Wrapping delta-chain sequence (meaningful for `Codec::Delta`).
    chain_seq: u8,
    buffer: Buffer, // payload left empty; filled by the caller
    caps: Option<Caps>,
    payload_start: usize,
    payload_len: usize,
}

fn parse_header(frame: &[u8]) -> Result<ParsedHeader> {
    if frame.len() < FIXED + 8 || &frame[..4] != WIRE_MAGIC {
        return Err(Error::Serial("not an EdgeFrame (bad magic/short)".into()));
    }
    if frame[4] != VERSION {
        return Err(Error::Serial(format!("EdgeFrame version {} unsupported", frame[4])));
    }
    let codec = codec_from_wire(frame[6])?;
    let flags = frame[5];
    let chain_seq = frame[7];
    let pts = opt(read_u64(frame, 8)?);
    let duration = opt(read_u64(frame, 16)?);
    let base_universal = opt(read_u64(frame, 24)?);
    let client_id = opt(read_u64(frame, 32)?);
    let seq = opt(read_u64(frame, 40)?);
    let capture_universal = opt(read_u64(frame, 48)?);
    let caps_len = read_u32(frame, 56)? as usize;
    let caps_end = 60 + caps_len;
    if frame.len() < caps_end + 4 {
        return Err(Error::Serial("EdgeFrame caps truncated".into()));
    }
    let caps = if caps_len == 0 {
        None
    } else {
        let s = std::str::from_utf8(&frame[60..caps_end])
            .map_err(|e| Error::Serial(format!("caps not utf8: {e}")))?;
        Some(Caps::parse(s)?)
    };
    let payload_len = read_u32(frame, caps_end)? as usize;
    let payload_start = caps_end + 4;
    if frame.len() != payload_start + payload_len {
        return Err(Error::Serial(format!(
            "EdgeFrame length {} != declared {}",
            frame.len(),
            payload_start + payload_len
        )));
    }
    let buffer = Buffer {
        pts,
        duration,
        data: Bytes::new(),
        meta: Meta {
            client_id,
            seq,
            remote_base_universal: base_universal,
            capture_universal,
            origin: None,
        },
    };
    Ok(ParsedHeader { codec, flags, chain_seq, buffer, caps, payload_start, payload_len })
}

/// Streaming-inflate a compressed payload view into one fresh
/// `Bytes`-backed allocation (moved, never copied), with the
/// decompressed-size guard enforced incrementally during inflation.
fn inflate_payload(view: &[u8]) -> Result<Bytes> {
    Ok(Bytes::from(compress::inflate_guarded(view, MAX_DECOMPRESSED)?))
}

/// Reconstruct the dense payload of a sparse-codec frame: concatenated
/// COO tensors decoded back to dense, with the cumulative size bounded
/// like the inflate path (each tensor is additionally capped by
/// `sparse::MAX_DENSE_DECODED`).
fn sparse_payload_to_dense(view: &[u8]) -> Result<Bytes> {
    if view.is_empty() {
        return Err(Error::Serial("sparse frame with empty payload".into()));
    }
    let mut dense: Vec<u8> = Vec::new();
    let mut off = 0;
    while off < view.len() {
        let len = sparse::encoded_len(&view[off..])
            .map_err(|e| Error::Serial(format!("sparse payload: {e}")))?;
        let (_, d) = sparse::decode_prefix(&view[off..])
            .map_err(|e| Error::Serial(format!("sparse payload: {e}")))?;
        off += len;
        // Single-tensor frames (the common case) skip the assembly copy.
        if dense.is_empty() && off == view.len() {
            return Ok(Bytes::from(d));
        }
        if dense.len() as u64 + d.len() as u64 > MAX_DECOMPRESSED {
            return Err(Error::Serial(format!(
                "sparse frame expands past the {MAX_DECOMPRESSED}-byte limit"
            )));
        }
        dense.extend_from_slice(&d);
    }
    Ok(Bytes::from(dense))
}

/// Stateless payload decode for the codecs that need no link history.
/// `Codec::Delta` is accepted only for keyframes (which are plain
/// full-frame deflates); mid-chain deltas need a [`LinkDecoder`].
fn decode_payload_stateless(frame: &Bytes, p: &ParsedHeader) -> Result<Bytes> {
    match p.codec {
        Codec::None => Ok(frame.slice(p.payload_start..p.payload_start + p.payload_len)),
        Codec::Zlib => inflate_payload(&frame[p.payload_start..]),
        Codec::Delta if p.flags & FLAG_KEYFRAME != 0 => {
            inflate_payload(&frame[p.payload_start..])
        }
        Codec::Delta => Err(Error::Serial(
            "delta frame without link state (mid-chain; decode with a LinkDecoder)".into(),
        )),
        Codec::Sparse => sparse_payload_to_dense(&frame[p.payload_start..]),
        Codec::Auto => unreachable!("codec_from_wire rejects the Auto discriminant"),
    }
}

/// Decode a shared frame into (Buffer, Option<Caps>) — the output
/// buffer's payload is a slice view into `frame` (zero copy) for
/// `Codec::None`; compressed frames inflate straight out of the frame
/// view into one fresh allocation (guarded against bombs mid-stream).
///
/// Stateless: delta-codec frames decode only when they are keyframes.
/// Long-lived links hold a [`LinkDecoder`], which tracks the delta
/// chain and degrades gracefully under loss.
pub fn decode_shared(frame: &Bytes) -> Result<(Buffer, Option<Caps>)> {
    let p = parse_header(frame)?;
    let data = decode_payload_stateless(frame, &p)?;
    let mut buffer = p.buffer;
    buffer.data = data;
    Ok((buffer, p.caps))
}

/// Decode a borrowed frame (compat; copies the payload out once).
pub fn decode(frame: &[u8]) -> Result<(Buffer, Option<Caps>)> {
    let p = parse_header(frame)?;
    let mut buffer = p.buffer;
    buffer.data = match p.codec {
        Codec::None => Bytes::copy_from_slice(&frame[p.payload_start..]),
        Codec::Sparse => sparse_payload_to_dense(&frame[p.payload_start..])?,
        Codec::Delta if p.flags & FLAG_KEYFRAME == 0 => {
            return Err(Error::Serial(
                "delta frame without link state (mid-chain; decode with a LinkDecoder)".into(),
            ))
        }
        _ => inflate_payload(&frame[p.payload_start..])?,
    };
    Ok((buffer, p.caps))
}

/// Per-link decode state, symmetric to [`LinkCodec`]: tracks the
/// previous reconstructed payload and the delta-chain sequence so
/// delta frames can be applied — and, after loss or reorder breaks the
/// chain, *detected* and dropped until the next keyframe instead of
/// being reconstructed corrupt.
///
/// One `LinkDecoder` per ordered frame stream (a subscription, a TCP
/// connection): frames from different senders must not share one.
pub struct LinkDecoder {
    prev: Option<Bytes>,
    expect_seq: u8,
    synced: bool,
    m_resyncs: Option<std::sync::Arc<crate::metrics::Counter>>,
}

impl LinkDecoder {
    /// `link` names the metrics scope (`codec.delta.<link>.resyncs`);
    /// empty disables metrics (tests, short-lived links).
    pub fn new(link: &str) -> Self {
        Self {
            prev: None,
            expect_seq: 0,
            synced: false,
            m_resyncs: (!link.is_empty())
                .then(|| crate::metrics::global().counter(&format!("codec.delta.{link}.resyncs"))),
        }
    }

    /// Forget the chain (reconnect: the peer will re-key).
    pub fn reset(&mut self) {
        self.prev = None;
        self.synced = false;
    }

    /// Is the delta chain currently intact? (tests/observability)
    pub fn is_synced(&self) -> bool {
        self.synced
    }

    /// Decode one frame of this link's ordered stream.
    ///
    /// `Ok(None)` means a mid-chain delta arrived while the chain is
    /// broken (frames were lost or reordered upstream): the frame is
    /// dropped — never delivered corrupt — and delivery resumes at the
    /// next keyframe. Non-delta codecs decode exactly like
    /// [`decode_shared`].
    pub fn decode(&mut self, frame: &Bytes) -> Result<Option<(Buffer, Option<Caps>)>> {
        let p = parse_header(frame)?;
        let data = match p.codec {
            Codec::Delta if p.flags & FLAG_KEYFRAME != 0 => {
                let data = inflate_payload(&frame[p.payload_start..])?;
                self.prev = Some(data.clone());
                self.expect_seq = p.chain_seq.wrapping_add(1);
                self.synced = true;
                data
            }
            Codec::Delta => {
                if !self.synced || p.chain_seq != self.expect_seq || self.prev.is_none() {
                    self.desync();
                    return Ok(None);
                }
                let prev = self.prev.clone().expect("synced chain has a previous frame");
                let mut residue =
                    compress::inflate_guarded(&frame[p.payload_start..], MAX_DECOMPRESSED)?;
                if residue.len() != prev.len() {
                    // Inconsistent chain the sequence check missed (e.g.
                    // u8 aliasing after a very long loss window): drop,
                    // never deliver corrupt data.
                    self.desync();
                    return Ok(None);
                }
                delta::apply_delta(&mut residue, &prev)?;
                let data = Bytes::from(residue);
                self.prev = Some(data.clone());
                self.expect_seq = self.expect_seq.wrapping_add(1);
                data
            }
            _ => decode_payload_stateless(frame, &p)?,
        };
        let mut buffer = p.buffer;
        buffer.data = data;
        Ok(Some((buffer, p.caps)))
    }

    /// The chain broke: count the event once per break and drop deltas
    /// until the next keyframe.
    fn desync(&mut self) {
        if self.synced {
            if let Some(m) = &self.m_resyncs {
                m.inc();
            }
        }
        self.synced = false;
        self.prev = None;
    }
}

/// Read one length-prefixed EdgeFrame from a stream reader.
///
/// This is the receive hop's single payload allocation; decode the result
/// with [`decode_shared`] to keep it shared.
pub fn read_frame<R: std::io::Read>(r: &mut R) -> Result<Bytes> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > 512 * 1024 * 1024 {
        return Err(Error::Serial(format!("frame length {n} exceeds limit")));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(Bytes::from(buf))
}

/// Write one length-prefixed frame from a contiguous slice.
pub fn write_frame<W: std::io::Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    w.write_all(&(frame.len() as u32).to_le_bytes())?;
    w.write_all(frame)?;
    Ok(())
}

/// Write one length-prefixed [`WireFrame`] with a single vectored call
/// (length prefix + header + payload; no assembly copy).
pub fn write_frame_vectored<W: std::io::Write>(w: &mut W, frame: &WireFrame) -> Result<()> {
    let len = (frame.len() as u32).to_le_bytes();
    write_all_vectored(w, &[&len[..], frame.header.as_slice(), frame.payload.as_slice()])?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_buffer() -> Buffer {
        let mut b = Buffer::new(vec![1u8, 2, 3, 4, 5]).with_pts(123).with_duration(16_666_667);
        b.meta.remote_base_universal = Some(999);
        b.meta.client_id = Some(7);
        b.meta.seq = Some(42);
        b.meta.capture_universal = Some(1234567);
        b
    }

    #[test]
    fn roundtrip_plain() {
        let b = sample_buffer();
        let caps = Caps::video(4, 4, 30);
        let frame = encode(&b, Some(&caps), Codec::None).unwrap();
        let (b2, c2) = decode(&frame).unwrap();
        assert_eq!(b2, b);
        assert_eq!(c2.unwrap(), caps);
    }

    #[test]
    fn roundtrip_zlib() {
        let b = Buffer::new(vec![9u8; 50_000]).with_pts(5);
        let frame = encode(&b, None, Codec::Zlib).unwrap();
        assert!(frame.len() < 5_000);
        let (b2, c2) = decode(&frame).unwrap();
        assert_eq!(&b2.data[..], &b.data[..]);
        assert!(c2.is_none());
    }

    #[test]
    fn vectored_encode_shares_payload_for_none_codec() {
        let b = sample_buffer();
        let f = encode_vectored(&b, Some(&Caps::video(4, 4, 30)), Codec::None).unwrap();
        assert!(f.payload.same_backing(&b.data), "encode must not copy the payload");
        assert_eq!(f.to_vec(), encode(&b, Some(&Caps::video(4, 4, 30)), Codec::None).unwrap());
    }

    #[test]
    fn decode_shared_is_a_view_into_the_frame() {
        let b = sample_buffer();
        let frame = Bytes::from(encode(&b, None, Codec::None).unwrap());
        let (b2, _) = decode_shared(&frame).unwrap();
        assert_eq!(b2, b);
        assert!(b2.data.same_backing(&frame), "decode must not copy the payload");
    }

    #[test]
    fn zlib_frame_is_one_allocation() {
        let b = Buffer::new(vec![9u8; 50_000]).with_pts(5);
        let f = encode_vectored(&b, Some(&Caps::video(4, 4, 30)), Codec::Zlib).unwrap();
        assert!(
            f.header.same_backing(&f.payload),
            "compressed header and payload must share one backing allocation"
        );
        assert!(f.payload.len() < b.data.len() / 10);
        let (b2, c2) = decode_shared(&Bytes::from(f.to_vec())).unwrap();
        assert_eq!(&b2.data[..], &b.data[..]);
        assert_eq!(c2.unwrap(), Caps::video(4, 4, 30));
    }

    #[test]
    fn zlib_vectored_matches_contiguous_encode() {
        let b = sample_buffer();
        let f = encode_vectored(&b, None, Codec::Zlib).unwrap();
        assert_eq!(f.to_vec(), encode(&b, None, Codec::Zlib).unwrap());
    }

    #[test]
    fn auto_codec_resolves_per_frame() {
        use crate::util::rng::XorShift64;
        // Compressible payload -> Auto lands on zlib (single allocation).
        let b = Buffer::new(vec![1u8; 40_000]);
        let f = encode_vectored(&b, None, Codec::Auto).unwrap();
        assert!(f.payload.len() < b.data.len());
        assert!(f.header.same_backing(&f.payload));
        // Incompressible payload -> Auto falls back to pass-through and
        // the payload is shared, not copied.
        let mut noise = vec![0u8; 40_000];
        XorShift64::new(7).fill_bytes(&mut noise);
        let bn = Buffer::new(noise);
        let fn_ = encode_vectored(&bn, None, Codec::Auto).unwrap();
        assert!(fn_.payload.same_backing(&bn.data), "incompressible Auto frame must share");
        // Both decode transparently (the wire flag says what happened).
        let (d1, _) = decode_shared(&Bytes::from(f.to_vec())).unwrap();
        let (d2, _) = decode_shared(&Bytes::from(fn_.to_vec())).unwrap();
        assert_eq!(&d1.data[..], &b.data[..]);
        assert_eq!(&d2.data[..], &bn.data[..]);
    }

    #[test]
    fn unknown_wire_codec_flag_rejected() {
        let b = Buffer::new(vec![1, 2, 3]);
        let mut frame = encode(&b, None, Codec::None).unwrap();
        for flag in [2u8, 9, 255] {
            frame[6] = flag;
            match decode(&frame) {
                Err(Error::Serial(msg)) => assert!(msg.contains("codec"), "{msg}"),
                other => panic!("codec flag {flag}: expected Serial error, got {other:?}"),
            }
            match decode_shared(&Bytes::from(frame.clone())) {
                Err(Error::Serial(_)) => {}
                other => panic!("codec flag {flag}: expected Serial error, got {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_zlib_payload_rejected() {
        let b = Buffer::new(vec![4u8; 30_000]);
        let f = encode_vectored(&b, None, Codec::Zlib).unwrap();
        let hlen = f.header.len();
        let mut raw = f.to_vec();
        raw.truncate(raw.len() - 1);
        // Keep the declared length consistent so the framing check passes
        // and the *inflater* sees the truncation.
        let plen = (f.payload.len() - 1) as u32;
        raw[hlen - 4..hlen].copy_from_slice(&plen.to_le_bytes());
        match decode_shared(&Bytes::from(raw)) {
            Err(Error::Serial(_)) => {}
            other => panic!("expected Serial error, got {other:?}"),
        }
    }

    #[test]
    fn decode_shared_zlib_allocates_fresh() {
        let b = Buffer::new(vec![3u8; 10_000]);
        let frame = Bytes::from(encode(&b, None, Codec::Zlib).unwrap());
        let (b2, _) = decode_shared(&frame).unwrap();
        assert_eq!(&b2.data[..], &b.data[..]);
        assert!(!b2.data.same_backing(&frame));
    }

    #[test]
    fn absent_fields_stay_absent() {
        let b = Buffer::new(vec![1]);
        let frame = encode(&b, None, Codec::None).unwrap();
        let (b2, _) = decode(&frame).unwrap();
        assert_eq!(b2.pts, None);
        assert_eq!(b2.duration, None);
        assert_eq!(b2.meta.client_id, None);
        assert_eq!(b2.meta.seq, None);
        assert_eq!(b2.meta.remote_base_universal, None);
        assert_eq!(b2.meta.capture_universal, None);
    }

    #[test]
    fn corrupt_frames_rejected() {
        let b = sample_buffer();
        let frame = encode(&b, Some(&Caps::video(4, 4, 30)), Codec::None).unwrap();
        assert!(decode(&frame[..frame.len() - 1]).is_err());
        assert!(decode(&frame[..10]).is_err());
        let mut bad = frame.clone();
        bad[0] = b'X';
        assert!(decode(&bad).is_err());
        let mut badver = frame;
        badver[4] = 99;
        assert!(decode(&badver).is_err());
    }

    #[test]
    fn stream_framing_roundtrip() {
        let b = sample_buffer();
        let frame = encode(&b, None, Codec::None).unwrap();
        let mut wire = Vec::new();
        write_frame(&mut wire, &frame).unwrap();
        write_frame(&mut wire, &frame).unwrap();
        let mut r = std::io::Cursor::new(wire);
        assert_eq!(&read_frame(&mut r).unwrap()[..], frame.as_slice());
        assert_eq!(&read_frame(&mut r).unwrap()[..], frame.as_slice());
        assert!(read_frame(&mut r).is_err()); // EOF
    }

    #[test]
    fn vectored_framing_matches_contiguous() {
        let b = sample_buffer();
        let vf = encode_vectored(&b, Some(&Caps::video(4, 4, 30)), Codec::None).unwrap();
        let mut wire_v = Vec::new();
        write_frame_vectored(&mut wire_v, &vf).unwrap();
        let mut wire_c = Vec::new();
        write_frame(&mut wire_c, &vf.to_vec()).unwrap();
        assert_eq!(wire_v, wire_c);
        let mut r = std::io::Cursor::new(wire_v);
        let received = read_frame(&mut r).unwrap();
        let (b2, c2) = decode_shared(&received).unwrap();
        assert_eq!(b2, b);
        assert_eq!(c2.unwrap(), Caps::video(4, 4, 30));
    }

    #[test]
    fn oversize_frame_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut r = std::io::Cursor::new(wire);
        assert!(read_frame(&mut r).is_err());
    }

    // -- stateful per-link codec stack (Delta / Sparse / extended Auto) --

    /// A correlated frame sequence: each frame perturbs a few bytes of
    /// the previous one (video-like tensor traffic).
    fn correlated_frames(n: usize, len: usize) -> Vec<Buffer> {
        let mut cur = vec![7u8; len];
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            for j in (i % 97..len).step_by(809) {
                cur[j] = cur[j].wrapping_add(i as u8 + 1);
            }
            out.push(Buffer::new(cur.clone()).with_pts(i as u64));
        }
        out
    }

    #[test]
    fn delta_link_roundtrips_and_deltas_are_small() {
        let mut enc = LinkCodec::new(Codec::Delta, "");
        let mut dec = LinkDecoder::new("");
        let frames = correlated_frames(20, 60_000);
        let mut delta_bytes = 0usize;
        let mut keyframes = 0;
        for b in &frames {
            let f = enc.encode(b, None).unwrap();
            assert!(f.header.same_backing(&f.payload), "delta frame must be one allocation");
            let raw = Bytes::from(f.to_vec());
            if raw[5] & FLAG_KEYFRAME != 0 {
                keyframes += 1;
            } else {
                delta_bytes += f.payload.len();
            }
            let (b2, _) = dec.decode(&raw).unwrap().expect("lossless link never drops");
            assert_eq!(&b2.data[..], &b.data[..]);
            assert_eq!(b2.pts, b.pts);
        }
        // 20 frames at the default interval of 16 -> exactly 2 keyframes.
        assert_eq!(keyframes, 2);
        // 18 correlated deltas of 60 KB frames must cost almost nothing
        // on the wire (~1.08 MB raw).
        assert!(delta_bytes < 20_000, "delta bytes {delta_bytes}");
    }

    #[test]
    fn delta_payload_size_change_forces_keyframe() {
        let mut enc = LinkCodec::new(Codec::Delta, "");
        let mut dec = LinkDecoder::new("");
        for len in [1000usize, 1000, 2000, 2000] {
            let b = Buffer::new(vec![3u8; len]);
            let f = Bytes::from(enc.encode(&b, None).unwrap().to_vec());
            let (b2, _) = dec.decode(&f).unwrap().unwrap();
            assert_eq!(b2.data.len(), len);
        }
    }

    #[test]
    fn decoder_drops_deltas_after_loss_until_next_keyframe() {
        let mut enc = LinkCodec::new(Codec::Delta, "");
        enc.set_keyframe_interval(8);
        let mut dec = LinkDecoder::new("");
        let frames = correlated_frames(24, 10_000);
        let encoded: Vec<Bytes> =
            frames.iter().map(|b| Bytes::from(enc.encode(b, None).unwrap().to_vec())).collect();
        // Lose frames 2..5 (mid-chain deltas).
        let mut delivered = Vec::new();
        for (i, f) in encoded.iter().enumerate() {
            if (2..5).contains(&i) {
                continue;
            }
            if let Some((b, _)) = dec.decode(f).unwrap() {
                delivered.push(i);
                // Whatever is delivered must be byte-exact, never a
                // corrupt reconstruction.
                assert_eq!(&b.data[..], &frames[i].data[..], "frame {i}");
            }
        }
        // Frames 5..8 are dropped (broken chain); 8 is the next
        // keyframe and everything from there is delivered.
        assert_eq!(delivered, vec![0, 1, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21, 22, 23]);
        assert!(!delivered.contains(&5));
    }

    #[test]
    fn decoder_detects_reorder() {
        let mut enc = LinkCodec::new(Codec::Delta, "");
        let mut dec = LinkDecoder::new("");
        let frames = correlated_frames(4, 5_000);
        let encoded: Vec<Bytes> =
            frames.iter().map(|b| Bytes::from(enc.encode(b, None).unwrap().to_vec())).collect();
        assert!(dec.decode(&encoded[0]).unwrap().is_some()); // keyframe
        assert!(dec.decode(&encoded[2]).unwrap().is_none(), "skipped seq must drop");
        assert!(dec.decode(&encoded[1]).unwrap().is_none(), "stale seq must drop");
        assert!(!dec.is_synced());
    }

    #[test]
    fn stateless_decode_accepts_keyframes_rejects_mid_chain_deltas() {
        let mut enc = LinkCodec::new(Codec::Delta, "");
        let frames = correlated_frames(2, 1_000);
        let kf = Bytes::from(enc.encode(&frames[0], None).unwrap().to_vec());
        let df = Bytes::from(enc.encode(&frames[1], None).unwrap().to_vec());
        let (b, _) = decode_shared(&kf).unwrap();
        assert_eq!(&b.data[..], &frames[0].data[..]);
        let e = decode_shared(&df).unwrap_err();
        assert!(e.to_string().contains("LinkDecoder"), "{e}");
        assert!(decode(&df.to_vec()).is_err());
    }

    #[test]
    fn non_delta_frame_on_link_breaks_chain_and_rekeys() {
        let mut enc = LinkCodec::new(Codec::Delta, "");
        let frames = correlated_frames(3, 2_000);
        let f0 = Bytes::from(enc.encode(&frames[0], None).unwrap().to_vec());
        assert!(f0[5] & FLAG_KEYFRAME != 0);
        // Simulate a reconnect: history gone, next frame re-keys.
        enc.reset_chain();
        let f1 = Bytes::from(enc.encode(&frames[1], None).unwrap().to_vec());
        assert!(f1[5] & FLAG_KEYFRAME != 0, "post-reset frame must be a keyframe");
        let f2 = Bytes::from(enc.encode(&frames[2], None).unwrap().to_vec());
        assert!(f2[5] & FLAG_KEYFRAME == 0);
        // A fresh decoder (the reconnected receiver) follows from f1.
        let mut dec = LinkDecoder::new("");
        assert!(dec.decode(&f1).unwrap().is_some());
        let (b2, _) = dec.decode(&f2).unwrap().unwrap();
        assert_eq!(&b2.data[..], &frames[2].data[..]);
    }

    fn sparse_caps_and_payload(len: usize, every: usize) -> (Caps, Vec<u8>) {
        use crate::tensor::{DType, TensorInfo, TensorsInfo};
        let info = TensorsInfo::one(TensorInfo::new(DType::F32, &[len as u32]).unwrap());
        let caps = Caps::tensors(&info);
        let mut vals = vec![0f32; len];
        for i in (0..len).step_by(every) {
            vals[i] = i as f32 + 1.0;
        }
        (caps, crate::tensor::f32_to_bytes(&vals))
    }

    #[test]
    fn sparse_link_roundtrips_and_beats_dense() {
        let (caps, payload) = sparse_caps_and_payload(10_000, 50); // 2% density
        let b = Buffer::new(payload.clone()).with_pts(1);
        let mut enc = LinkCodec::new(Codec::Sparse, "");
        let f = enc.encode(&b, Some(&caps)).unwrap();
        assert!(f.header.same_backing(&f.payload), "sparse frame must be one allocation");
        let raw = Bytes::from(f.to_vec());
        assert_eq!(raw[6], Codec::Sparse as u8);
        assert!(f.payload.len() < payload.len() / 5, "sparse payload {}", f.payload.len());
        let (b2, c2) = decode_shared(&raw).unwrap();
        assert_eq!(&b2.data[..], &payload[..]);
        assert_eq!(c2.unwrap(), caps);
        // A LinkDecoder decodes it identically.
        let mut dec = LinkDecoder::new("");
        let (b3, _) = dec.decode(&raw).unwrap().unwrap();
        assert_eq!(&b3.data[..], &payload[..]);
    }

    #[test]
    fn sparse_link_falls_back_to_zlib_when_dense_or_inapplicable() {
        // Dense tensor payload: COO would grow the frame -> zlib flag.
        let (caps, _) = sparse_caps_and_payload(1_000, 1);
        let dense_vals: Vec<f32> = (1..=1000).map(|x| x as f32).collect();
        let b = Buffer::new(crate::tensor::f32_to_bytes(&dense_vals));
        let mut enc = LinkCodec::new(Codec::Sparse, "");
        let raw = Bytes::from(enc.encode(&b, Some(&caps)).unwrap().to_vec());
        assert_eq!(raw[6], Codec::Zlib as u8);
        assert_eq!(&decode_shared(&raw).unwrap().0.data[..], &b.data[..]);
        // No tensor caps at all -> zlib as well.
        let b2 = Buffer::new(vec![0u8; 4_000]);
        let raw2 = Bytes::from(enc.encode(&b2, None).unwrap().to_vec());
        assert_eq!(raw2[6], Codec::Zlib as u8);
    }

    #[test]
    fn auto_adopts_delta_on_correlated_stream() {
        let mut enc = LinkCodec::new(Codec::Auto, "auto-delta-test");
        let mut dec = LinkDecoder::new("");
        let frames = correlated_frames(80, 30_000);
        let mut wire_codecs = Vec::new();
        for b in &frames {
            let raw = Bytes::from(enc.encode(b, None).unwrap().to_vec());
            wire_codecs.push(raw[6]);
            let decoded = dec.decode(&raw).unwrap();
            if let Some((b2, _)) = decoded {
                assert_eq!(&b2.data[..], &b.data[..]);
            }
        }
        // After the second probe (frame 65) saw a valid previous frame,
        // the link must be riding the delta arm.
        assert!(
            wire_codecs[70..].iter().all(|&c| c == Codec::Delta as u8),
            "steady state should be delta: {:?}",
            &wire_codecs[60..]
        );
    }

    #[test]
    fn auto_adopts_sparse_on_sparse_tensors() {
        // One nonzero value in a 400 KiB tensor: COO is ~36 bytes while
        // even a run-length-friendly deflate of 400 KiB of zeros costs
        // kilobytes, so the probe must adopt the sparse arm outright.
        let (caps, payload) = sparse_caps_and_payload(100_000, 100_000);
        let mut enc = LinkCodec::new(Codec::Auto, "auto-sparse-test");
        let b = Buffer::new(payload);
        for i in 0..3 {
            let raw = Bytes::from(enc.encode(&b, Some(&caps)).unwrap().to_vec());
            assert_eq!(raw[6], Codec::Sparse as u8, "frame {i}");
            assert_eq!(&decode_shared(&raw).unwrap().0.data[..], &b.data[..]);
        }
    }

    #[test]
    fn auto_still_passes_through_on_noise() {
        use crate::util::rng::XorShift64;
        let mut enc = LinkCodec::new(Codec::Auto, "auto-noise-test");
        let mut rng = XorShift64::new(3);
        let mut none_frames = 0;
        for i in 0..10 {
            let mut noise = vec![0u8; 20_000];
            rng.fill_bytes(&mut noise);
            let b = Buffer::new(noise);
            let f = enc.encode(&b, None).unwrap();
            let raw = Bytes::from(f.to_vec());
            if raw[6] == Codec::None as u8 {
                none_frames += 1;
                assert!(f.payload.same_backing(&b.data), "pass-through must share payload");
            }
            // Frame 0 is the probe; everything after must be pass-through.
            if i > 0 {
                assert_eq!(raw[6], Codec::None as u8, "frame {i}");
            }
        }
        assert!(none_frames >= 9);
    }

    #[test]
    fn delta_frames_survive_stream_framing() {
        let mut enc = LinkCodec::new(Codec::Delta, "");
        let frames = correlated_frames(3, 8_000);
        let mut wire = Vec::new();
        for b in &frames {
            let f = enc.encode(b, Some(&Caps::video(4, 4, 30))).unwrap();
            write_frame_vectored(&mut wire, &f).unwrap();
        }
        let mut r = std::io::Cursor::new(wire);
        let mut dec = LinkDecoder::new("");
        for b in &frames {
            let raw = read_frame(&mut r).unwrap();
            let (b2, c2) = dec.decode(&raw).unwrap().unwrap();
            assert_eq!(&b2.data[..], &b.data[..]);
            assert_eq!(c2.unwrap(), Caps::video(4, 4, 30));
        }
    }
}
