//! EdgeFrame — the transport envelope every among-device connection uses
//! (mqttsink/src, zmqsink/src, query elements, NNStreamer-Edge analog).
//!
//! Carries the buffer payload plus everything a *remote* pipeline needs to
//! reconstruct the stream: the caps string (so the receiver negotiates
//! without out-of-band schema — §4.2.1), timestamps + publisher base-time
//! (§4.2.3 sync), query routing ids (§4.2.2), and the compression codec.
//!
//! Layout (little-endian):
//! ```text
//! "EPEF" | ver u8 | flags u8 | codec u8 | pad u8
//! pts u64 | duration u64 | base_universal u64 | client_id u64 | seq u64 | capture_universal u64
//! caps_len u32 | caps utf8 | payload_len u32 | payload (possibly compressed)
//! ```
//! `u64::MAX` encodes "absent" for the optional u64 fields.
//!
//! ## Zero-copy data path
//!
//! The hot path never assembles a contiguous frame:
//!
//! - [`encode_vectored`] returns a [`WireFrame`] — a small header `Bytes`
//!   (fixed fields + caps + payload length) and the buffer's payload
//!   `Bytes` shared as-is (`Codec::None` adds **zero** payload copies).
//!   For `Codec::Zlib` the streaming compressor deflates directly onto
//!   the header being assembled, so the whole compressed frame is ONE
//!   allocation with header/payload as two views into it.
//! - [`encode_vectored_auto`] is the per-link adaptive variant backing
//!   `Codec::Auto` (skips deflate on streams that sample incompressible).
//! - [`write_frame_vectored`] / [`WireFrame::write_to`] emit both parts
//!   with one scatter-gather write.
//! - [`read_frame`] performs the hop's single allocation (one `Bytes` per
//!   received frame) and [`decode_shared`] returns a `Buffer` whose
//!   payload is a slice *view* into that allocation (`Codec::None`), or
//!   streams the inflater straight out of the frame view into one fresh
//!   allocation (`Codec::Zlib`) — ≤ 2 payload allocations per compressed
//!   hop, with the decompressed-size guard enforced mid-stream.
//!
//! The contiguous [`encode`]/[`decode`] entry points remain for
//! borrowed-slice callers and tests; their copies are counted by
//! [`crate::buffer::bytes`].

use crate::buffer::{Buffer, Bytes, Meta};
use crate::caps::Caps;
use crate::serial::compress::{self, AutoCodec, Codec, MAX_DECOMPRESSED};
use crate::util::{read_u32, read_u64, write_all_vectored, Error, Result};

pub const WIRE_MAGIC: &[u8; 4] = b"EPEF";
const VERSION: u8 = 1;
const FIXED: usize = 8 + 6 * 8;
const ABSENT: u64 = u64::MAX;

/// An encoded EdgeFrame as two independently shareable parts: everything
/// before the payload, and the payload itself. Cloning is O(1); the same
/// frame can be fanned out to N subscribers without duplication.
#[derive(Debug, Clone)]
pub struct WireFrame {
    /// Fixed fields + caps string + payload-length prefix.
    pub header: Bytes,
    /// Payload bytes — for `Codec::None` this *is* the buffer's payload.
    pub payload: Bytes,
}

impl WireFrame {
    /// Total encoded length (header + payload).
    pub fn len(&self) -> usize {
        self.header.len() + self.payload.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Assemble into one contiguous `Vec` (counted copy; compat/tests).
    pub fn to_vec(&self) -> Vec<u8> {
        crate::buffer::record_copy(self.len());
        let mut out = Vec::with_capacity(self.len());
        out.extend_from_slice(&self.header);
        out.extend_from_slice(&self.payload);
        out
    }

    /// Write header + payload with one vectored call (no assembly copy).
    pub fn write_to<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        write_all_vectored(w, &[self.header.as_slice(), self.payload.as_slice()])
    }
}

/// Append everything of an EdgeFrame header that precedes the
/// payload-length field. `codec` must already be resolved (`None`/`Zlib`);
/// `Auto` is a policy and never reaches the wire.
fn push_header_fields(out: &mut Vec<u8>, buf: &Buffer, caps_str: &str, codec: Codec) {
    debug_assert!(codec != Codec::Auto, "Codec::Auto must be resolved before encoding");
    out.extend_from_slice(WIRE_MAGIC);
    out.push(VERSION);
    out.push(0); // flags (reserved)
    out.push(codec as u8);
    out.push(0);
    for v in [
        buf.pts.unwrap_or(ABSENT),
        buf.duration.unwrap_or(ABSENT),
        buf.meta.remote_base_universal.unwrap_or(ABSENT),
        buf.meta.client_id.unwrap_or(ABSENT),
        buf.meta.seq.unwrap_or(ABSENT),
        buf.meta.capture_universal.unwrap_or(ABSENT),
    ] {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(&(caps_str.len() as u32).to_le_bytes());
    out.extend_from_slice(caps_str.as_bytes());
}

/// Pass-through frame: header in its own small allocation, payload shared
/// from the buffer as-is (zero payload copies).
fn encode_none(buf: &Buffer, caps_str: &str) -> WireFrame {
    let mut header = Vec::with_capacity(FIXED + caps_str.len() + 8);
    push_header_fields(&mut header, buf, caps_str, Codec::None);
    header.extend_from_slice(&(buf.data.len() as u32).to_le_bytes());
    WireFrame { header: Bytes::from(header), payload: buf.data.clone() }
}

/// Compressed frame as ONE allocation: the streaming compressor deflates
/// the payload directly onto the tail of the header being assembled, the
/// payload-length field is patched in afterwards, and header/payload are
/// returned as two views into that single backing buffer.
fn encode_zlib(buf: &Buffer, caps_str: &str) -> Result<WireFrame> {
    let mut frame = Vec::with_capacity(FIXED + caps_str.len() + 8 + buf.data.len() / 2 + 64);
    push_header_fields(&mut frame, buf, caps_str, Codec::Zlib);
    frame.extend_from_slice(&0u32.to_le_bytes()); // payload_len, patched below
    let payload_start = frame.len();
    let n = compress::deflate_into(&mut frame, &buf.data)?;
    if n > u32::MAX as usize {
        return Err(Error::Serial(format!("compressed payload {n} exceeds u32 framing")));
    }
    frame[payload_start - 4..payload_start].copy_from_slice(&(n as u32).to_le_bytes());
    let all = Bytes::from(frame);
    Ok(WireFrame { header: all.slice(..payload_start), payload: all.slice(payload_start..) })
}

/// Encode a buffer (+ its caps) into a [`WireFrame`] without copying the
/// payload when `codec == Codec::None`, and without an intermediate
/// compressed buffer when `codec == Codec::Zlib`.
///
/// `Codec::Auto` here is resolved statelessly: the frame is deflated once
/// and kept only if compression actually shrank the payload. Links that
/// encode many frames should hold an [`AutoCodec`] and use
/// [`encode_vectored_auto`] instead, which learns to skip deflate
/// entirely on incompressible streams.
pub fn encode_vectored(buf: &Buffer, caps: Option<&Caps>, codec: Codec) -> Result<WireFrame> {
    let caps_str = caps.map(|c| c.to_string()).unwrap_or_default();
    match codec {
        Codec::None => Ok(encode_none(buf, &caps_str)),
        Codec::Zlib => encode_zlib(buf, &caps_str),
        Codec::Auto => {
            let f = encode_zlib(buf, &caps_str)?;
            if f.payload.len() < buf.data.len() {
                Ok(f)
            } else {
                Ok(encode_none(buf, &caps_str))
            }
        }
    }
}

/// Adaptive encode for a long-lived link: `auto` decides per frame
/// whether deflate is worth paying for (sampling achieved ratios and
/// recording decisions in per-link metrics), and a compressed frame that
/// fails to shrink the payload is demoted to `Codec::None` on the wire.
pub fn encode_vectored_auto(
    buf: &Buffer,
    caps: Option<&Caps>,
    auto: &mut AutoCodec,
) -> Result<WireFrame> {
    let caps_str = caps.map(|c| c.to_string()).unwrap_or_default();
    match auto.next_codec() {
        Codec::Zlib => {
            let f = encode_zlib(buf, &caps_str)?;
            auto.record_zlib(buf.data.len(), f.payload.len());
            if f.payload.len() < buf.data.len() {
                Ok(f)
            } else {
                Ok(encode_none(buf, &caps_str))
            }
        }
        _ => {
            auto.record_none();
            Ok(encode_none(buf, &caps_str))
        }
    }
}

/// Encode into one contiguous `Vec` (compat; copies the payload once).
pub fn encode(buf: &Buffer, caps: Option<&Caps>, codec: Codec) -> Result<Vec<u8>> {
    Ok(encode_vectored(buf, caps, codec)?.to_vec())
}

/// Per-link encode state: the configured codec plus the adaptive sampler
/// backing `Codec::Auto`. Transport elements hold one of these per link
/// so they all share a single dispatch (and a single place to evolve the
/// Auto policy) instead of each re-implementing it.
pub struct LinkCodec {
    codec: Codec,
    auto: Option<AutoCodec>,
}

impl LinkCodec {
    /// `link` names the per-link metrics scope (`codec.auto.<link>.*`);
    /// it is only consulted when `codec == Codec::Auto`.
    pub fn new(codec: Codec, link: &str) -> Self {
        Self { codec, auto: (codec == Codec::Auto).then(|| AutoCodec::new(link)) }
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Encode one frame with this link's codec (adaptive for `Auto`).
    pub fn encode(&mut self, buf: &Buffer, caps: Option<&Caps>) -> Result<WireFrame> {
        match &mut self.auto {
            Some(auto) => encode_vectored_auto(buf, caps, auto),
            None => encode_vectored(buf, caps, self.codec),
        }
    }
}

fn codec_from_wire(b: u8) -> Result<Codec> {
    Ok(match b {
        0 => Codec::None,
        1 => Codec::Zlib,
        other => return Err(Error::Serial(format!("unknown wire codec {other}"))),
    })
}

fn opt(v: u64) -> Option<u64> {
    if v == ABSENT {
        None
    } else {
        Some(v)
    }
}

/// Header fields parsed out of a frame, with the payload's byte range.
struct ParsedHeader {
    codec: Codec,
    buffer: Buffer, // payload left empty; filled by the caller
    caps: Option<Caps>,
    payload_start: usize,
    payload_len: usize,
}

fn parse_header(frame: &[u8]) -> Result<ParsedHeader> {
    if frame.len() < FIXED + 8 || &frame[..4] != WIRE_MAGIC {
        return Err(Error::Serial("not an EdgeFrame (bad magic/short)".into()));
    }
    if frame[4] != VERSION {
        return Err(Error::Serial(format!("EdgeFrame version {} unsupported", frame[4])));
    }
    let codec = codec_from_wire(frame[6])?;
    let pts = opt(read_u64(frame, 8)?);
    let duration = opt(read_u64(frame, 16)?);
    let base_universal = opt(read_u64(frame, 24)?);
    let client_id = opt(read_u64(frame, 32)?);
    let seq = opt(read_u64(frame, 40)?);
    let capture_universal = opt(read_u64(frame, 48)?);
    let caps_len = read_u32(frame, 56)? as usize;
    let caps_end = 60 + caps_len;
    if frame.len() < caps_end + 4 {
        return Err(Error::Serial("EdgeFrame caps truncated".into()));
    }
    let caps = if caps_len == 0 {
        None
    } else {
        let s = std::str::from_utf8(&frame[60..caps_end])
            .map_err(|e| Error::Serial(format!("caps not utf8: {e}")))?;
        Some(Caps::parse(s)?)
    };
    let payload_len = read_u32(frame, caps_end)? as usize;
    let payload_start = caps_end + 4;
    if frame.len() != payload_start + payload_len {
        return Err(Error::Serial(format!(
            "EdgeFrame length {} != declared {}",
            frame.len(),
            payload_start + payload_len
        )));
    }
    let buffer = Buffer {
        pts,
        duration,
        data: Bytes::new(),
        meta: Meta {
            client_id,
            seq,
            remote_base_universal: base_universal,
            capture_universal,
            origin: None,
        },
    };
    Ok(ParsedHeader { codec, buffer, caps, payload_start, payload_len })
}

/// Streaming-inflate a compressed payload view into one fresh
/// `Bytes`-backed allocation (moved, never copied), with the
/// decompressed-size guard enforced incrementally during inflation.
fn inflate_payload(view: &[u8]) -> Result<Bytes> {
    Ok(Bytes::from(compress::inflate_guarded(view, MAX_DECOMPRESSED)?))
}

/// Decode a shared frame into (Buffer, Option<Caps>) — the output
/// buffer's payload is a slice view into `frame` (zero copy) for
/// `Codec::None`; compressed frames inflate straight out of the frame
/// view into one fresh allocation (guarded against bombs mid-stream).
pub fn decode_shared(frame: &Bytes) -> Result<(Buffer, Option<Caps>)> {
    let p = parse_header(frame)?;
    let mut buffer = p.buffer;
    buffer.data = match p.codec {
        Codec::None => frame.slice(p.payload_start..p.payload_start + p.payload_len),
        _ => inflate_payload(&frame[p.payload_start..])?,
    };
    Ok((buffer, p.caps))
}

/// Decode a borrowed frame (compat; copies the payload out once).
pub fn decode(frame: &[u8]) -> Result<(Buffer, Option<Caps>)> {
    let p = parse_header(frame)?;
    let mut buffer = p.buffer;
    buffer.data = match p.codec {
        Codec::None => Bytes::copy_from_slice(&frame[p.payload_start..]),
        _ => inflate_payload(&frame[p.payload_start..])?,
    };
    Ok((buffer, p.caps))
}

/// Read one length-prefixed EdgeFrame from a stream reader.
///
/// This is the receive hop's single payload allocation; decode the result
/// with [`decode_shared`] to keep it shared.
pub fn read_frame<R: std::io::Read>(r: &mut R) -> Result<Bytes> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > 512 * 1024 * 1024 {
        return Err(Error::Serial(format!("frame length {n} exceeds limit")));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(Bytes::from(buf))
}

/// Write one length-prefixed frame from a contiguous slice.
pub fn write_frame<W: std::io::Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    w.write_all(&(frame.len() as u32).to_le_bytes())?;
    w.write_all(frame)?;
    Ok(())
}

/// Write one length-prefixed [`WireFrame`] with a single vectored call
/// (length prefix + header + payload; no assembly copy).
pub fn write_frame_vectored<W: std::io::Write>(w: &mut W, frame: &WireFrame) -> Result<()> {
    let len = (frame.len() as u32).to_le_bytes();
    write_all_vectored(w, &[&len[..], frame.header.as_slice(), frame.payload.as_slice()])?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_buffer() -> Buffer {
        let mut b = Buffer::new(vec![1u8, 2, 3, 4, 5]).with_pts(123).with_duration(16_666_667);
        b.meta.remote_base_universal = Some(999);
        b.meta.client_id = Some(7);
        b.meta.seq = Some(42);
        b.meta.capture_universal = Some(1234567);
        b
    }

    #[test]
    fn roundtrip_plain() {
        let b = sample_buffer();
        let caps = Caps::video(4, 4, 30);
        let frame = encode(&b, Some(&caps), Codec::None).unwrap();
        let (b2, c2) = decode(&frame).unwrap();
        assert_eq!(b2, b);
        assert_eq!(c2.unwrap(), caps);
    }

    #[test]
    fn roundtrip_zlib() {
        let b = Buffer::new(vec![9u8; 50_000]).with_pts(5);
        let frame = encode(&b, None, Codec::Zlib).unwrap();
        assert!(frame.len() < 5_000);
        let (b2, c2) = decode(&frame).unwrap();
        assert_eq!(&b2.data[..], &b.data[..]);
        assert!(c2.is_none());
    }

    #[test]
    fn vectored_encode_shares_payload_for_none_codec() {
        let b = sample_buffer();
        let f = encode_vectored(&b, Some(&Caps::video(4, 4, 30)), Codec::None).unwrap();
        assert!(f.payload.same_backing(&b.data), "encode must not copy the payload");
        assert_eq!(f.to_vec(), encode(&b, Some(&Caps::video(4, 4, 30)), Codec::None).unwrap());
    }

    #[test]
    fn decode_shared_is_a_view_into_the_frame() {
        let b = sample_buffer();
        let frame = Bytes::from(encode(&b, None, Codec::None).unwrap());
        let (b2, _) = decode_shared(&frame).unwrap();
        assert_eq!(b2, b);
        assert!(b2.data.same_backing(&frame), "decode must not copy the payload");
    }

    #[test]
    fn zlib_frame_is_one_allocation() {
        let b = Buffer::new(vec![9u8; 50_000]).with_pts(5);
        let f = encode_vectored(&b, Some(&Caps::video(4, 4, 30)), Codec::Zlib).unwrap();
        assert!(
            f.header.same_backing(&f.payload),
            "compressed header and payload must share one backing allocation"
        );
        assert!(f.payload.len() < b.data.len() / 10);
        let (b2, c2) = decode_shared(&Bytes::from(f.to_vec())).unwrap();
        assert_eq!(&b2.data[..], &b.data[..]);
        assert_eq!(c2.unwrap(), Caps::video(4, 4, 30));
    }

    #[test]
    fn zlib_vectored_matches_contiguous_encode() {
        let b = sample_buffer();
        let f = encode_vectored(&b, None, Codec::Zlib).unwrap();
        assert_eq!(f.to_vec(), encode(&b, None, Codec::Zlib).unwrap());
    }

    #[test]
    fn auto_codec_resolves_per_frame() {
        use crate::util::rng::XorShift64;
        // Compressible payload -> Auto lands on zlib (single allocation).
        let b = Buffer::new(vec![1u8; 40_000]);
        let f = encode_vectored(&b, None, Codec::Auto).unwrap();
        assert!(f.payload.len() < b.data.len());
        assert!(f.header.same_backing(&f.payload));
        // Incompressible payload -> Auto falls back to pass-through and
        // the payload is shared, not copied.
        let mut noise = vec![0u8; 40_000];
        XorShift64::new(7).fill_bytes(&mut noise);
        let bn = Buffer::new(noise);
        let fn_ = encode_vectored(&bn, None, Codec::Auto).unwrap();
        assert!(fn_.payload.same_backing(&bn.data), "incompressible Auto frame must share");
        // Both decode transparently (the wire flag says what happened).
        let (d1, _) = decode_shared(&Bytes::from(f.to_vec())).unwrap();
        let (d2, _) = decode_shared(&Bytes::from(fn_.to_vec())).unwrap();
        assert_eq!(&d1.data[..], &b.data[..]);
        assert_eq!(&d2.data[..], &bn.data[..]);
    }

    #[test]
    fn unknown_wire_codec_flag_rejected() {
        let b = Buffer::new(vec![1, 2, 3]);
        let mut frame = encode(&b, None, Codec::None).unwrap();
        for flag in [2u8, 9, 255] {
            frame[6] = flag;
            match decode(&frame) {
                Err(Error::Serial(msg)) => assert!(msg.contains("codec"), "{msg}"),
                other => panic!("codec flag {flag}: expected Serial error, got {other:?}"),
            }
            match decode_shared(&Bytes::from(frame.clone())) {
                Err(Error::Serial(_)) => {}
                other => panic!("codec flag {flag}: expected Serial error, got {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_zlib_payload_rejected() {
        let b = Buffer::new(vec![4u8; 30_000]);
        let f = encode_vectored(&b, None, Codec::Zlib).unwrap();
        let hlen = f.header.len();
        let mut raw = f.to_vec();
        raw.truncate(raw.len() - 1);
        // Keep the declared length consistent so the framing check passes
        // and the *inflater* sees the truncation.
        let plen = (f.payload.len() - 1) as u32;
        raw[hlen - 4..hlen].copy_from_slice(&plen.to_le_bytes());
        match decode_shared(&Bytes::from(raw)) {
            Err(Error::Serial(_)) => {}
            other => panic!("expected Serial error, got {other:?}"),
        }
    }

    #[test]
    fn decode_shared_zlib_allocates_fresh() {
        let b = Buffer::new(vec![3u8; 10_000]);
        let frame = Bytes::from(encode(&b, None, Codec::Zlib).unwrap());
        let (b2, _) = decode_shared(&frame).unwrap();
        assert_eq!(&b2.data[..], &b.data[..]);
        assert!(!b2.data.same_backing(&frame));
    }

    #[test]
    fn absent_fields_stay_absent() {
        let b = Buffer::new(vec![1]);
        let frame = encode(&b, None, Codec::None).unwrap();
        let (b2, _) = decode(&frame).unwrap();
        assert_eq!(b2.pts, None);
        assert_eq!(b2.duration, None);
        assert_eq!(b2.meta.client_id, None);
        assert_eq!(b2.meta.seq, None);
        assert_eq!(b2.meta.remote_base_universal, None);
        assert_eq!(b2.meta.capture_universal, None);
    }

    #[test]
    fn corrupt_frames_rejected() {
        let b = sample_buffer();
        let frame = encode(&b, Some(&Caps::video(4, 4, 30)), Codec::None).unwrap();
        assert!(decode(&frame[..frame.len() - 1]).is_err());
        assert!(decode(&frame[..10]).is_err());
        let mut bad = frame.clone();
        bad[0] = b'X';
        assert!(decode(&bad).is_err());
        let mut badver = frame;
        badver[4] = 99;
        assert!(decode(&badver).is_err());
    }

    #[test]
    fn stream_framing_roundtrip() {
        let b = sample_buffer();
        let frame = encode(&b, None, Codec::None).unwrap();
        let mut wire = Vec::new();
        write_frame(&mut wire, &frame).unwrap();
        write_frame(&mut wire, &frame).unwrap();
        let mut r = std::io::Cursor::new(wire);
        assert_eq!(&read_frame(&mut r).unwrap()[..], frame.as_slice());
        assert_eq!(&read_frame(&mut r).unwrap()[..], frame.as_slice());
        assert!(read_frame(&mut r).is_err()); // EOF
    }

    #[test]
    fn vectored_framing_matches_contiguous() {
        let b = sample_buffer();
        let vf = encode_vectored(&b, Some(&Caps::video(4, 4, 30)), Codec::None).unwrap();
        let mut wire_v = Vec::new();
        write_frame_vectored(&mut wire_v, &vf).unwrap();
        let mut wire_c = Vec::new();
        write_frame(&mut wire_c, &vf.to_vec()).unwrap();
        assert_eq!(wire_v, wire_c);
        let mut r = std::io::Cursor::new(wire_v);
        let received = read_frame(&mut r).unwrap();
        let (b2, c2) = decode_shared(&received).unwrap();
        assert_eq!(b2, b);
        assert_eq!(c2.unwrap(), Caps::video(4, 4, 30));
    }

    #[test]
    fn oversize_frame_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut r = std::io::Cursor::new(wire);
        assert!(read_frame(&mut r).is_err());
    }
}
