//! Tensor element types for `other/tensors` streams (NNStreamer set).

use crate::util::{Error, Result};

/// Element type of a tensor stream. Wire ids are stable (used in flexible
/// frame headers and sparse encodings) — do not reorder.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum DType {
    I8 = 0,
    U8 = 1,
    I16 = 2,
    U16 = 3,
    I32 = 4,
    U32 = 5,
    I64 = 6,
    U64 = 7,
    F32 = 8,
    F64 = 9,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::I8 | DType::U8 => 1,
            DType::I16 | DType::U16 => 2,
            DType::I32 | DType::U32 | DType::F32 => 4,
            DType::I64 | DType::U64 | DType::F64 => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::I8 => "int8",
            DType::U8 => "uint8",
            DType::I16 => "int16",
            DType::U16 => "uint16",
            DType::I32 => "int32",
            DType::U32 => "uint32",
            DType::I64 => "int64",
            DType::U64 => "uint64",
            DType::F32 => "float32",
            DType::F64 => "float64",
        }
    }

    /// Parse the NNStreamer caps spelling (e.g. `float32`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "int8" => DType::I8,
            "uint8" => DType::U8,
            "int16" => DType::I16,
            "uint16" => DType::U16,
            "int32" => DType::I32,
            "uint32" => DType::U32,
            "int64" => DType::I64,
            "uint64" => DType::U64,
            "float32" => DType::F32,
            "float64" => DType::F64,
            other => return Err(Error::Tensor(format!("unknown dtype `{other}`"))),
        })
    }

    pub fn from_wire(id: u8) -> Result<Self> {
        Ok(match id {
            0 => DType::I8,
            1 => DType::U8,
            2 => DType::I16,
            3 => DType::U16,
            4 => DType::I32,
            5 => DType::U32,
            6 => DType::I64,
            7 => DType::U64,
            8 => DType::F32,
            9 => DType::F64,
            other => return Err(Error::Tensor(format!("unknown dtype wire id {other}"))),
        })
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

pub const ALL_DTYPES: [DType; 10] = [
    DType::I8,
    DType::U8,
    DType::I16,
    DType::U16,
    DType::I32,
    DType::U32,
    DType::I64,
    DType::U64,
    DType::F32,
    DType::F64,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::U8.size(), 1);
        assert_eq!(DType::I16.size(), 2);
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::F64.size(), 8);
    }

    #[test]
    fn name_parse_roundtrip() {
        for d in ALL_DTYPES {
            assert_eq!(DType::parse(d.name()).unwrap(), d);
        }
    }

    #[test]
    fn wire_roundtrip() {
        for d in ALL_DTYPES {
            assert_eq!(DType::from_wire(d as u8).unwrap(), d);
        }
    }

    #[test]
    fn unknown_rejected() {
        assert!(DType::parse("bfloat16").is_err());
        assert!(DType::from_wire(200).is_err());
    }
}
