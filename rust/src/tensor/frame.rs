//! Frame payload layouts for `other/tensors` streams.
//!
//! - **static**: raw concatenation of tensor payloads; the shape lives in
//!   the negotiated caps only (no per-frame header) — R2's default.
//! - **flexible** (`format=flexible`): every frame starts with a header
//!   declaring per-tensor dtype/dims, so dimension and type may vary per
//!   frame (dynamic schema, §4.1).
//!
//! Sparse is a separate per-tensor encoding — see [`crate::tensor::sparse`].

use crate::buffer::Bytes;
use crate::tensor::{DType, TensorInfo, TensorsInfo, MAX_RANK, MAX_TENSORS};
use crate::util::{read_u32, Error, Result};

/// Stream format of an `other/tensors` pad (§4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Format {
    #[default]
    Static,
    Flexible,
    Sparse,
}

impl Format {
    pub fn name(self) -> &'static str {
        match self {
            Format::Static => "static",
            Format::Flexible => "flexible",
            Format::Sparse => "sparse",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "static" => Format::Static,
            "flexible" => Format::Flexible,
            "sparse" => Format::Sparse,
            other => return Err(Error::Tensor(format!("unknown format `{other}`"))),
        })
    }
}

/// Magic prefix of a flexible frame header.
pub const FLEX_MAGIC: &[u8; 4] = b"EPFX";
const FLEX_VERSION: u8 = 1;
/// Per-tensor header entry size: dtype(1) rank(1) pad(2) dims(16) size(4).
const ENTRY: usize = 24;

/// Encode a flexible frame: header + concatenated payloads.
///
/// `parts` pairs each tensor's metadata with its payload; payload length
/// must equal `info.size()`.
pub fn encode_flexible(parts: &[(TensorInfo, &[u8])]) -> Result<Vec<u8>> {
    if parts.is_empty() || parts.len() > MAX_TENSORS {
        return Err(Error::Tensor(format!("{} tensors out of 1..={MAX_TENSORS}", parts.len())));
    }
    let payload: usize = parts.iter().map(|(_, p)| p.len()).sum();
    let mut out = Vec::with_capacity(8 + parts.len() * ENTRY + payload);
    out.extend_from_slice(FLEX_MAGIC);
    out.push(FLEX_VERSION);
    out.push(parts.len() as u8);
    out.extend_from_slice(&[0u8, 0u8]);
    for (info, p) in parts {
        if p.len() != info.size() {
            return Err(Error::Tensor(format!(
                "payload {} != declared size {} for dims {:?}",
                p.len(),
                info.size(),
                info.dims
            )));
        }
        out.push(info.dtype as u8);
        out.push(MAX_RANK as u8);
        out.extend_from_slice(&[0u8, 0u8]);
        for d in info.dims {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(&(p.len() as u32).to_le_bytes());
    }
    for (_, p) in parts {
        out.extend_from_slice(p);
    }
    Ok(out)
}

/// Decoded view of a flexible frame: metadata plus payload byte ranges
/// (offsets into the original frame buffer).
#[derive(Debug, Clone, PartialEq)]
pub struct FlexFrame {
    pub info: TensorsInfo,
    pub ranges: Vec<std::ops::Range<usize>>,
}

/// Decode a flexible frame header; validates sizes against the buffer.
pub fn decode_flexible(buf: &[u8]) -> Result<FlexFrame> {
    if buf.len() < 8 || &buf[..4] != FLEX_MAGIC {
        return Err(Error::Tensor("not a flexible tensor frame (bad magic)".into()));
    }
    if buf[4] != FLEX_VERSION {
        return Err(Error::Tensor(format!("flexible frame version {} unsupported", buf[4])));
    }
    let n = buf[5] as usize;
    if n == 0 || n > MAX_TENSORS {
        return Err(Error::Tensor(format!("flexible frame declares {n} tensors")));
    }
    let header_end = 8 + n * ENTRY;
    if buf.len() < header_end {
        return Err(Error::Tensor("flexible frame header truncated".into()));
    }
    let mut info = TensorsInfo::default();
    let mut ranges = Vec::with_capacity(n);
    let mut off = header_end;
    for i in 0..n {
        let e = 8 + i * ENTRY;
        let dtype = DType::from_wire(buf[e])?;
        let mut dims = [1u32; MAX_RANK];
        for (j, d) in dims.iter_mut().enumerate() {
            *d = read_u32(buf, e + 4 + j * 4)?;
        }
        let size = read_u32(buf, e + 20)? as usize;
        let ti = TensorInfo::new(dtype, &dims)?;
        if ti.size() != size {
            return Err(Error::Tensor(format!(
                "flexible entry {i}: declared size {size} != dims size {}",
                ti.size()
            )));
        }
        if buf.len() < off + size {
            return Err(Error::Tensor(format!("flexible frame payload truncated at tensor {i}")));
        }
        ranges.push(off..off + size);
        info.push(ti)?;
        off += size;
    }
    if off != buf.len() {
        return Err(Error::Tensor(format!("flexible frame has {} trailing bytes", buf.len() - off)));
    }
    Ok(FlexFrame { info, ranges })
}

/// Convert a static frame (payload + its negotiated info) into flexible.
pub fn static_to_flexible(info: &TensorsInfo, payload: &[u8]) -> Result<Vec<u8>> {
    if payload.len() != info.frame_size() {
        return Err(Error::Tensor(format!(
            "static frame {} bytes != info {}",
            payload.len(),
            info.frame_size()
        )));
    }
    let mut parts = Vec::with_capacity(info.len());
    let mut off = 0;
    for t in &info.tensors {
        parts.push((t.clone(), &payload[off..off + t.size()]));
        off += t.size();
    }
    encode_flexible(&parts)
}

/// Strip a flexible header, returning the static payload (concatenated
/// tensors) and the per-frame info.
pub fn flexible_to_static(buf: &[u8]) -> Result<(TensorsInfo, Vec<u8>)> {
    let f = decode_flexible(buf)?;
    let mut payload = Vec::with_capacity(buf.len());
    for r in &f.ranges {
        crate::buffer::record_copy(r.len());
        payload.extend_from_slice(&buf[r.clone()]);
    }
    Ok((f.info, payload))
}

/// Zero-copy variant of [`flexible_to_static`]: the tensor payloads of a
/// flexible frame are laid out contiguously after the header (validated
/// by [`decode_flexible`]), so the static payload is a slice view into
/// the shared frame — no copy.
pub fn flexible_to_static_shared(buf: &Bytes) -> Result<(TensorsInfo, Bytes)> {
    let f = decode_flexible(buf)?;
    let start = f.ranges.first().map(|r| r.start).unwrap_or(buf.len());
    Ok((f.info, buf.slice(start..buf.len())))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(dims: &[u32]) -> TensorInfo {
        TensorInfo::new(DType::F32, dims).unwrap()
    }

    #[test]
    fn format_parse_roundtrip() {
        for f in [Format::Static, Format::Flexible, Format::Sparse] {
            assert_eq!(Format::parse(f.name()).unwrap(), f);
        }
        assert!(Format::parse("dense").is_err());
    }

    #[test]
    fn flexible_roundtrip_single() {
        let t = info(&[2, 3]);
        let payload: Vec<u8> = (0..t.size() as u8).map(|x| x).collect();
        let frame = encode_flexible(&[(t.clone(), &payload)]).unwrap();
        let dec = decode_flexible(&frame).unwrap();
        assert_eq!(dec.info.tensors[0].dims, t.dims);
        assert_eq!(&frame[dec.ranges[0].clone()], payload.as_slice());
    }

    #[test]
    fn flexible_roundtrip_multi() {
        let a = info(&[4, 20]);
        let b = TensorInfo::new(DType::U8, &[7]).unwrap();
        let pa = vec![1u8; a.size()];
        let pb = vec![2u8; b.size()];
        let frame = encode_flexible(&[(a.clone(), &pa), (b.clone(), &pb)]).unwrap();
        let dec = decode_flexible(&frame).unwrap();
        assert_eq!(dec.info.len(), 2);
        assert_eq!(dec.info.tensors[1].dtype, DType::U8);
        assert_eq!(&frame[dec.ranges[1].clone()], pb.as_slice());
    }

    #[test]
    fn flexible_detects_truncation() {
        let t = info(&[8]);
        let payload = vec![0u8; t.size()];
        let mut frame = encode_flexible(&[(t, &payload)]).unwrap();
        frame.truncate(frame.len() - 1);
        assert!(decode_flexible(&frame).is_err());
    }

    #[test]
    fn flexible_detects_trailing_garbage() {
        let t = info(&[8]);
        let payload = vec![0u8; t.size()];
        let mut frame = encode_flexible(&[(t, &payload)]).unwrap();
        frame.push(0xAA);
        assert!(decode_flexible(&frame).is_err());
    }

    #[test]
    fn flexible_rejects_bad_magic() {
        assert!(decode_flexible(b"XXXX....").is_err());
        assert!(decode_flexible(b"EP").is_err());
    }

    #[test]
    fn payload_size_mismatch_rejected() {
        let t = info(&[4]);
        let bad = vec![0u8; 3];
        assert!(encode_flexible(&[(t, &bad)]).is_err());
    }

    #[test]
    fn flexible_to_static_shared_is_a_view() {
        let mut ti = TensorsInfo::default();
        ti.push(info(&[2, 2])).unwrap();
        ti.push(TensorInfo::new(DType::U8, &[3]).unwrap()).unwrap();
        let payload: Vec<u8> = (0..ti.frame_size() as u8).collect();
        let flex = Bytes::from(static_to_flexible(&ti, &payload).unwrap());
        let (info2, shared) = flexible_to_static_shared(&flex).unwrap();
        assert_eq!(info2, ti);
        assert_eq!(&shared[..], payload.as_slice());
        assert!(shared.same_backing(&flex), "flex->static must not copy");
    }

    #[test]
    fn static_flexible_roundtrip() {
        let mut ti = TensorsInfo::default();
        ti.push(info(&[2, 2])).unwrap();
        ti.push(TensorInfo::new(DType::U8, &[3]).unwrap()).unwrap();
        let payload: Vec<u8> = (0..ti.frame_size() as u8).collect();
        let flex = static_to_flexible(&ti, &payload).unwrap();
        let (info2, payload2) = flexible_to_static(&flex).unwrap();
        assert_eq!(info2, ti);
        assert_eq!(payload2, payload);
    }

    #[test]
    fn varying_dims_per_frame() {
        // The §4.1 motivation: cropped-video streams vary per frame.
        for w in [3u32, 5, 9] {
            let t = TensorInfo::new(DType::U8, &[3, w, w]).unwrap();
            let payload = vec![7u8; t.size()];
            let frame = encode_flexible(&[(t, &payload)]).unwrap();
            let dec = decode_flexible(&frame).unwrap();
            assert_eq!(dec.info.tensors[0].dims[1], w);
        }
    }
}
