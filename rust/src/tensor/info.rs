//! Tensor shape metadata: `TensorInfo` (one tensor) and `TensorsInfo`
//! (a frame of up to [`MAX_TENSORS`] tensors) plus the NNStreamer caps
//! dimension spelling `d0:d1:d2:d3` (innermost first, rank ≤ 4).

use crate::tensor::DType;
use crate::util::{Error, Result};

/// NNStreamer limit: one stream frame carries at most 16 tensors.
pub const MAX_TENSORS: usize = 16;
/// NNStreamer rank limit.
pub const MAX_RANK: usize = 4;

/// Shape + type of a single tensor. `dims` is innermost-first, padded with
/// trailing 1s to rank 4 in the caps spelling (e.g. `4:20:1:1`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorInfo {
    pub name: Option<String>,
    pub dtype: DType,
    pub dims: [u32; MAX_RANK],
}

impl TensorInfo {
    pub fn new(dtype: DType, dims: &[u32]) -> Result<Self> {
        if dims.is_empty() || dims.len() > MAX_RANK {
            return Err(Error::Tensor(format!("rank {} out of 1..=4", dims.len())));
        }
        if dims.iter().any(|&d| d == 0) {
            return Err(Error::Tensor(format!("zero dimension in {dims:?}")));
        }
        let mut out = [1u32; MAX_RANK];
        out[..dims.len()].copy_from_slice(dims);
        Ok(Self { name: None, dtype, dims: out })
    }

    pub fn with_name(mut self, name: impl Into<String>) -> Self {
        self.name = Some(name.into());
        self
    }

    /// Number of elements.
    pub fn count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }

    /// Payload size in bytes.
    pub fn size(&self) -> usize {
        self.count() * self.dtype.size()
    }

    /// Caps spelling: `4:20:1:1`.
    pub fn dims_string(&self) -> String {
        self.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(":")
    }

    /// Parse the caps spelling (1..=4 colon-separated dims).
    pub fn parse_dims(s: &str) -> Result<[u32; MAX_RANK]> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.is_empty() || parts.len() > MAX_RANK {
            return Err(Error::Tensor(format!("bad dims `{s}`")));
        }
        let mut dims = [1u32; MAX_RANK];
        for (i, p) in parts.iter().enumerate() {
            dims[i] = p
                .trim()
                .parse::<u32>()
                .map_err(|_| Error::Tensor(format!("bad dim `{p}` in `{s}`")))?;
            if dims[i] == 0 {
                return Err(Error::Tensor(format!("zero dim in `{s}`")));
            }
        }
        Ok(dims)
    }
}

/// Metadata for a whole frame: the ordered list of tensors.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TensorsInfo {
    pub tensors: Vec<TensorInfo>,
}

impl TensorsInfo {
    pub fn one(info: TensorInfo) -> Self {
        Self { tensors: vec![info] }
    }

    pub fn push(&mut self, info: TensorInfo) -> Result<()> {
        if self.tensors.len() >= MAX_TENSORS {
            return Err(Error::Tensor(format!("more than {MAX_TENSORS} tensors")));
        }
        self.tensors.push(info);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total frame payload size in bytes (static format: concatenated).
    pub fn frame_size(&self) -> usize {
        self.tensors.iter().map(|t| t.size()).sum()
    }

    /// Caps fields: `num_tensors=4,dimensions=4:20:1:1.20:1:1:1,types=...`
    /// NNStreamer separates per-tensor dims with `.` and types with `,`
    /// inside a quoted string; we follow the same spelling.
    pub fn dimensions_string(&self) -> String {
        self.tensors.iter().map(|t| t.dims_string()).collect::<Vec<_>>().join(".")
    }

    pub fn types_string(&self) -> String {
        self.tensors.iter().map(|t| t.dtype.name().to_string()).collect::<Vec<_>>().join(".")
    }

    /// Parse from caps fields. `dims`/`types` use `.` separators (we also
    /// accept `,` for compatibility with the paper's listings).
    pub fn from_caps_fields(num: usize, dims: &str, types: &str) -> Result<Self> {
        let sep = |s: &str| -> Vec<String> {
            s.split(['.', ','])
                .map(|x| x.trim().trim_matches('"').to_string())
                .filter(|x| !x.is_empty())
                .collect()
        };
        // "4:20:1:1.20:1:1:1" — but ',' split would break "4:20:1:1,20:1:1:1"
        // only if '.' unused; handle both by splitting on '.' first, then ','.
        let dim_parts: Vec<String> =
            if dims.contains('.') { dims.split('.').map(|s| s.trim().to_string()).collect() } else { sep(dims) };
        let type_parts: Vec<String> =
            if types.contains('.') { types.split('.').map(|s| s.trim().to_string()).collect() } else { sep(types) };
        if dim_parts.len() != num || type_parts.len() != num {
            return Err(Error::Tensor(format!(
                "num_tensors={num} but {} dims / {} types",
                dim_parts.len(),
                type_parts.len()
            )));
        }
        let mut info = TensorsInfo::default();
        for (d, t) in dim_parts.iter().zip(&type_parts) {
            let dims = TensorInfo::parse_dims(d)?;
            let dtype = DType::parse(t.trim_matches('"'))?;
            info.push(TensorInfo { name: None, dtype, dims })?;
        }
        Ok(info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn info_size_and_count() {
        let t = TensorInfo::new(DType::F32, &[4, 20]).unwrap();
        assert_eq!(t.count(), 80);
        assert_eq!(t.size(), 320);
        assert_eq!(t.dims, [4, 20, 1, 1]);
    }

    #[test]
    fn rank_limits_enforced() {
        assert!(TensorInfo::new(DType::U8, &[]).is_err());
        assert!(TensorInfo::new(DType::U8, &[1, 2, 3, 4, 5]).is_err());
        assert!(TensorInfo::new(DType::U8, &[0, 2]).is_err());
    }

    #[test]
    fn dims_string_roundtrip() {
        let t = TensorInfo::new(DType::F32, &[3, 300, 300]).unwrap();
        assert_eq!(t.dims_string(), "3:300:300:1");
        assert_eq!(TensorInfo::parse_dims(&t.dims_string()).unwrap(), t.dims);
    }

    #[test]
    fn parse_dims_rejects_garbage() {
        assert!(TensorInfo::parse_dims("a:b").is_err());
        assert!(TensorInfo::parse_dims("1:2:3:4:5").is_err());
        assert!(TensorInfo::parse_dims("0:1").is_err());
    }

    #[test]
    fn tensors_info_frame_size() {
        let mut ti = TensorsInfo::default();
        ti.push(TensorInfo::new(DType::F32, &[4, 20]).unwrap()).unwrap();
        ti.push(TensorInfo::new(DType::F32, &[20]).unwrap()).unwrap();
        assert_eq!(ti.frame_size(), 320 + 80);
    }

    #[test]
    fn max_tensors_enforced() {
        let mut ti = TensorsInfo::default();
        for _ in 0..MAX_TENSORS {
            ti.push(TensorInfo::new(DType::U8, &[1]).unwrap()).unwrap();
        }
        assert!(ti.push(TensorInfo::new(DType::U8, &[1]).unwrap()).is_err());
    }

    #[test]
    fn caps_fields_roundtrip_paper_listing() {
        // The exact decoder caps from Listing 2.
        let ti = TensorsInfo::from_caps_fields(
            4,
            "4:20:1:1,20:1:1:1,20:1:1:1,1:1:1:1",
            "float32,float32,float32,float32",
        )
        .unwrap();
        assert_eq!(ti.len(), 4);
        assert_eq!(ti.tensors[0].dims, [4, 20, 1, 1]);
        assert_eq!(ti.tensors[3].dims, [1, 1, 1, 1]);
        let again = TensorsInfo::from_caps_fields(4, &ti.dimensions_string(), &ti.types_string()).unwrap();
        assert_eq!(again, ti);
    }

    #[test]
    fn caps_fields_count_mismatch() {
        assert!(TensorsInfo::from_caps_fields(2, "1:1:1:1", "float32").is_err());
    }
}
