//! `other/tensors` — tensors as first-class stream citizens (§4.1).
//!
//! The stream data model of the paper: frames of up to 16 rank-≤4 tensors,
//! in one of three formats — `static` (shape in caps), `flexible`
//! (per-frame dynamic schema), `sparse` (COO, via converting elements).

pub mod dtype;
pub mod frame;
pub mod info;
pub mod sparse;

pub use dtype::DType;
pub use frame::{
    decode_flexible, encode_flexible, flexible_to_static, flexible_to_static_shared,
    static_to_flexible, FlexFrame, Format,
};
pub use info::{TensorInfo, TensorsInfo, MAX_RANK, MAX_TENSORS};

/// Helpers to view/build f32 tensor payloads (the models are f32-native).
pub fn f32_to_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32(buf: &[u8]) -> crate::util::Result<Vec<f32>> {
    if buf.len() % 4 != 0 {
        return Err(crate::util::Error::Tensor(format!("{} bytes not a multiple of 4", buf.len())));
    }
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bytes_roundtrip() {
        let v = vec![0.0f32, 1.5, -2.25, f32::MAX];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&v)).unwrap(), v);
    }

    #[test]
    fn bytes_to_f32_rejects_misaligned() {
        assert!(bytes_to_f32(&[1, 2, 3]).is_err());
    }
}
