//! Sparse tensor encoding: coordinate-list (COO) with linear indices
//! (§4.1 `format=sparse`, the compression clients requested for language
//! and speech models).
//!
//! Wire layout per tensor:
//! `"EPSP" | dtype u8 | rank u8 | pad u16 | dims 4xu32 | nnz u32 |
//!  indices nnz x u32 (linear, ascending) | values nnz x dtype.size()`
//!
//! The binary representation is intentionally NOT compatible with
//! static/flexible payloads (as in the paper), hence the dedicated
//! converting elements `tensor_sparse_enc` / `tensor_sparse_dec`.

use crate::tensor::{DType, TensorInfo, MAX_RANK};
use crate::util::{read_u32, Error, Result};

pub const SPARSE_MAGIC: &[u8; 4] = b"EPSP";
const HEADER: usize = 4 + 1 + 1 + 2 + 16 + 4;

/// Max dense size a decoded sparse tensor may claim (guards hostile
/// frames, mirroring `compress::MAX_DECOMPRESSED`): a 28-byte COO
/// header with huge dims must not trigger a multi-GiB allocation.
pub const MAX_DENSE_DECODED: usize = 256 * 1024 * 1024;

/// The tensor's real rank: dims with trailing 1s trimmed (min 1). This
/// is what travels in the wire rank byte — `TensorInfo` pads dims with
/// trailing 1s, so the trimmed form is the canonical one.
fn wire_rank(info: &TensorInfo) -> usize {
    info.dims.iter().rposition(|&d| d != 1).map_or(1, |i| i + 1)
}

/// Count the non-zero element slots of a dense payload (the encoded-size
/// predictor: COO stores exactly these plus the header).
pub fn count_nnz(info: &TensorInfo, dense: &[u8]) -> usize {
    let esz = info.dtype.size();
    dense.chunks_exact(esz).filter(|slot| slot.iter().any(|&b| b != 0)).count()
}

/// Encode a dense tensor payload into COO. Zero elements (all-zero bytes
/// of an element slot) are elided.
pub fn encode(info: &TensorInfo, dense: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(HEADER + count_nnz(info, dense) * (4 + info.dtype.size()));
    encode_into(info, dense, &mut out)?;
    Ok(out)
}

/// Encode a dense tensor payload into COO, appended directly onto `out`
/// (the frame being assembled — the wire path's one-allocation hop).
/// Returns the number of bytes written. Two scans of the payload, no
/// temporary index buffer.
pub fn encode_into(info: &TensorInfo, dense: &[u8], out: &mut Vec<u8>) -> Result<usize> {
    if dense.len() != info.size() {
        return Err(Error::Tensor(format!(
            "dense payload {} != info size {}",
            dense.len(),
            info.size()
        )));
    }
    let esz = info.dtype.size();
    let n = info.count();
    let start = out.len();
    out.extend_from_slice(SPARSE_MAGIC);
    out.push(info.dtype as u8);
    out.push(wire_rank(info) as u8);
    out.extend_from_slice(&[0, 0]);
    for d in info.dims {
        out.extend_from_slice(&d.to_le_bytes());
    }
    let nnz_pos = out.len();
    out.extend_from_slice(&0u32.to_le_bytes()); // nnz, patched below
    let mut nnz = 0u32;
    for i in 0..n {
        if dense[i * esz..(i + 1) * esz].iter().any(|&b| b != 0) {
            out.extend_from_slice(&(i as u32).to_le_bytes());
            nnz += 1;
        }
    }
    for i in 0..n {
        let slot = &dense[i * esz..(i + 1) * esz];
        if slot.iter().any(|&b| b != 0) {
            out.extend_from_slice(slot);
        }
    }
    out[nnz_pos..nnz_pos + 4].copy_from_slice(&nnz.to_le_bytes());
    Ok(out.len() - start)
}

/// Decode a COO tensor back to (info, dense payload).
pub fn decode(buf: &[u8]) -> Result<(TensorInfo, Vec<u8>)> {
    if buf.len() < HEADER || &buf[..4] != SPARSE_MAGIC {
        return Err(Error::Tensor("not a sparse tensor (bad magic)".into()));
    }
    let dtype = DType::from_wire(buf[4])?;
    let rank = buf[5] as usize;
    if rank == 0 || rank > MAX_RANK {
        return Err(Error::Tensor(format!("sparse tensor rank {rank} out of 1..={MAX_RANK}")));
    }
    let mut dims = [1u32; MAX_RANK];
    for (j, d) in dims.iter_mut().enumerate() {
        *d = read_u32(buf, 8 + j * 4)?;
    }
    if dims[rank..].iter().any(|&d| d != 1) {
        return Err(Error::Tensor(format!(
            "sparse tensor dims {dims:?} inconsistent with declared rank {rank}"
        )));
    }
    // Hostile-input guard: the claimed dense size comes straight off the
    // wire, so bound it (in overflow-safe math) BEFORE allocating — a
    // 28-byte frame must not demand a multi-GiB buffer.
    let claimed: u128 =
        dims.iter().map(|&d| d as u128).product::<u128>() * dtype.size() as u128;
    if claimed > MAX_DENSE_DECODED as u128 {
        return Err(Error::Tensor(format!(
            "sparse tensor claims {claimed} dense bytes, over the {MAX_DENSE_DECODED} limit"
        )));
    }
    let info = TensorInfo::new(dtype, &dims)?;
    let nnz = read_u32(buf, 24)? as usize;
    let esz = dtype.size();
    let idx_end = HEADER + nnz * 4;
    let val_end = idx_end + nnz * esz;
    if buf.len() != val_end {
        return Err(Error::Tensor(format!(
            "sparse tensor length {} != expected {val_end}",
            buf.len()
        )));
    }
    let count = info.count();
    let mut dense = vec![0u8; info.size()];
    let mut prev: Option<u32> = None;
    for k in 0..nnz {
        let i = read_u32(buf, HEADER + k * 4)?;
        if i as usize >= count {
            return Err(Error::Tensor(format!("sparse index {i} out of {count}")));
        }
        if let Some(p) = prev {
            if i <= p {
                return Err(Error::Tensor("sparse indices not ascending".into()));
            }
        }
        prev = Some(i);
        let src = idx_end + k * esz;
        dense[i as usize * esz..(i as usize + 1) * esz].copy_from_slice(&buf[src..src + esz]);
    }
    Ok((info, dense))
}

/// Total encoded length of the sparse tensor at the start of `buf`
/// (supports concatenated multi-tensor sparse frames).
pub fn encoded_len(buf: &[u8]) -> Result<usize> {
    if buf.len() < HEADER || &buf[..4] != SPARSE_MAGIC {
        return Err(Error::Tensor("not a sparse tensor (bad magic)".into()));
    }
    let dtype = DType::from_wire(buf[4])?;
    let nnz = read_u32(buf, 24)? as usize;
    Ok(HEADER + nnz * (4 + dtype.size()))
}

/// Decode the sparse tensor at the start of `buf`, ignoring trailing
/// bytes (use [`encoded_len`] to advance).
pub fn decode_prefix(buf: &[u8]) -> Result<(TensorInfo, Vec<u8>)> {
    let len = encoded_len(buf)?;
    if buf.len() < len {
        return Err(Error::Tensor("sparse tensor truncated".into()));
    }
    decode(&buf[..len])
}

/// Size of the encoded form for a given nnz (for bench reporting).
pub fn encoded_size(info: &TensorInfo, nnz: usize) -> usize {
    HEADER + nnz * (4 + info.dtype.size())
}

/// Density below which COO is smaller than dense for this dtype.
pub fn breakeven_density(dtype: DType) -> f64 {
    // dense = n*esz; coo ≈ n*density*(4+esz) + HEADER
    dtype.size() as f64 / (4.0 + dtype.size() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f32_payload(vals: &[f32]) -> Vec<u8> {
        vals.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    #[test]
    fn roundtrip_sparse_f32() {
        let info = TensorInfo::new(DType::F32, &[8]).unwrap();
        let dense = f32_payload(&[0.0, 1.5, 0.0, 0.0, -2.0, 0.0, 0.0, 3.0]);
        let enc = encode(&info, &dense).unwrap();
        let (info2, dense2) = decode(&enc).unwrap();
        assert_eq!(info2.dims, info.dims);
        assert_eq!(dense2, dense);
    }

    #[test]
    fn all_zero_encodes_compactly() {
        let info = TensorInfo::new(DType::F32, &[100]).unwrap();
        let dense = vec![0u8; info.size()];
        let enc = encode(&info, &dense).unwrap();
        assert_eq!(enc.len(), HEADER);
        let (_, dense2) = decode(&enc).unwrap();
        assert_eq!(dense2, dense);
    }

    #[test]
    fn dense_tensor_grows_but_roundtrips() {
        let info = TensorInfo::new(DType::U8, &[16]).unwrap();
        let dense: Vec<u8> = (1..=16).collect();
        let enc = encode(&info, &dense).unwrap();
        assert!(enc.len() > dense.len()); // COO overhead on dense data
        assert_eq!(decode(&enc).unwrap().1, dense);
    }

    #[test]
    fn sparse_saves_space_below_breakeven() {
        let info = TensorInfo::new(DType::F32, &[1000]).unwrap();
        let mut vals = vec![0f32; 1000];
        for i in (0..1000).step_by(50) {
            vals[i] = 1.0; // 2% density << breakeven 0.5
        }
        let enc = encode(&info, &f32_payload(&vals)).unwrap();
        assert!(enc.len() < info.size() / 5, "{} vs {}", enc.len(), info.size());
    }

    #[test]
    fn rejects_wrong_payload_size() {
        let info = TensorInfo::new(DType::F32, &[4]).unwrap();
        assert!(encode(&info, &[0u8; 3]).is_err());
    }

    #[test]
    fn rejects_corrupt_length() {
        let info = TensorInfo::new(DType::F32, &[4]).unwrap();
        let mut enc = encode(&info, &f32_payload(&[1.0, 0.0, 2.0, 0.0])).unwrap();
        enc.pop();
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn rejects_out_of_range_index() {
        let info = TensorInfo::new(DType::U8, &[4]).unwrap();
        let mut enc = encode(&info, &[0, 9, 0, 0]).unwrap();
        // index entry for the single nnz lives right after the header
        enc[HEADER] = 200;
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn rejects_non_ascending_indices() {
        let info = TensorInfo::new(DType::U8, &[4]).unwrap();
        let mut enc = encode(&info, &[0, 1, 2, 0]).unwrap();
        // two nnz at idx 1,2 -> swap them
        enc[HEADER] = 2;
        enc[HEADER + 4] = 1;
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn rank_byte_is_real_rank_and_roundtrips() {
        // Regression: the rank byte used to be hardcoded to MAX_RANK.
        for (dims, want_rank) in [
            (vec![8u32], 1u8),
            (vec![4, 20], 2),
            (vec![2, 3, 4], 3),
            (vec![2, 2, 2, 2], 4),
            (vec![5, 1, 1, 1], 1), // trailing 1s trim
        ] {
            let info = TensorInfo::new(DType::U8, &dims).unwrap();
            let dense: Vec<u8> = (0..info.size()).map(|x| (x % 7) as u8).collect();
            let enc = encode(&info, &dense).unwrap();
            assert_eq!(enc[5], want_rank, "dims {dims:?}");
            let (info2, dense2) = decode(&enc).unwrap();
            assert_eq!(info2.dims, info.dims);
            assert_eq!(dense2, dense);
        }
    }

    #[test]
    fn rejects_bad_rank_byte() {
        let info = TensorInfo::new(DType::U8, &[4]).unwrap();
        let good = encode(&info, &[0, 1, 0, 2]).unwrap();
        for rank in [0u8, (MAX_RANK + 1) as u8, 255] {
            let mut enc = good.clone();
            enc[5] = rank;
            let e = decode(&enc).unwrap_err();
            assert!(e.to_string().contains("rank"), "rank {rank}: {e}");
        }
    }

    #[test]
    fn rejects_dims_beyond_declared_rank() {
        let info = TensorInfo::new(DType::U8, &[4, 3]).unwrap();
        let dense = vec![1u8; info.size()];
        let mut enc = encode(&info, &dense).unwrap();
        assert_eq!(enc[5], 2);
        enc[5] = 1; // claim rank 1 while dims[1] == 3
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn decode_bomb_rejected_before_allocating() {
        // A header-only frame (nnz = 0) claiming huge dims passes the
        // length check; the dense-size cap must reject it up front.
        let info = TensorInfo::new(DType::F32, &[4]).unwrap();
        let template = encode(&info, &[0u8; 16]).unwrap();
        assert_eq!(template.len(), HEADER);
        // ~64 GiB claim: 65536 * 65536 * 4 elements of f32.
        let mut bomb = template.clone();
        for (j, d) in [65536u32, 65536, 4, 1].iter().enumerate() {
            bomb[8 + j * 4..12 + j * 4].copy_from_slice(&d.to_le_bytes());
        }
        bomb[5] = 3;
        let e = decode(&bomb).unwrap_err();
        assert!(e.to_string().contains("limit"), "{e}");
        // Overflow-hostile dims (product wraps every native width) are
        // also rejected cleanly, not wrapped into a small allocation.
        let mut wrap = template;
        for j in 0..MAX_RANK {
            wrap[8 + j * 4..12 + j * 4].copy_from_slice(&u32::MAX.to_le_bytes());
        }
        wrap[5] = MAX_RANK as u8;
        assert!(decode(&wrap).is_err());
        // At-the-limit claims still decode (an all-zero frame suffices).
        let big = TensorInfo::new(DType::U8, &[MAX_DENSE_DECODED as u32]).unwrap();
        let mut hdr = Vec::new();
        hdr.extend_from_slice(SPARSE_MAGIC);
        hdr.push(DType::U8 as u8);
        hdr.push(1);
        hdr.extend_from_slice(&[0, 0]);
        for d in big.dims {
            hdr.extend_from_slice(&d.to_le_bytes());
        }
        hdr.extend_from_slice(&0u32.to_le_bytes());
        let (info2, dense2) = decode(&hdr).unwrap();
        assert_eq!(info2.dims[0] as usize, MAX_DENSE_DECODED);
        assert_eq!(dense2.len(), MAX_DENSE_DECODED);
    }

    #[test]
    fn encode_into_appends_in_place() {
        let info = TensorInfo::new(DType::U8, &[8]).unwrap();
        let dense = [0u8, 3, 0, 0, 7, 0, 0, 1];
        let mut out = b"FRAME".to_vec();
        let n = encode_into(&info, &dense, &mut out).unwrap();
        assert_eq!(out.len(), 5 + n);
        assert_eq!(&out[..5], b"FRAME");
        assert_eq!(&out[5..], encode(&info, &dense).unwrap().as_slice());
        assert_eq!(count_nnz(&info, &dense), 3);
    }

    #[test]
    fn breakeven_math() {
        assert!((breakeven_density(DType::F32) - 0.5).abs() < 1e-9);
        assert!(breakeven_density(DType::U8) < breakeven_density(DType::F64));
    }

    #[test]
    fn encoded_size_matches_actual() {
        let info = TensorInfo::new(DType::F32, &[64]).unwrap();
        let mut vals = vec![0f32; 64];
        vals[3] = 1.0;
        vals[9] = 2.0;
        let enc = encode(&info, &f32_payload(&vals)).unwrap();
        assert_eq!(enc.len(), encoded_size(&info, 2));
    }
}
