//! Sparse tensor encoding: coordinate-list (COO) with linear indices
//! (§4.1 `format=sparse`, the compression clients requested for language
//! and speech models).
//!
//! Wire layout per tensor:
//! `"EPSP" | dtype u8 | rank u8 | pad u16 | dims 4xu32 | nnz u32 |
//!  indices nnz x u32 (linear, ascending) | values nnz x dtype.size()`
//!
//! The binary representation is intentionally NOT compatible with
//! static/flexible payloads (as in the paper), hence the dedicated
//! converting elements `tensor_sparse_enc` / `tensor_sparse_dec`.

use crate::tensor::{DType, TensorInfo, MAX_RANK};
use crate::util::{read_u32, Error, Result};

pub const SPARSE_MAGIC: &[u8; 4] = b"EPSP";
const HEADER: usize = 4 + 1 + 1 + 2 + 16 + 4;

/// Encode a dense tensor payload into COO. Zero elements (all-zero bytes
/// of an element slot) are elided.
pub fn encode(info: &TensorInfo, dense: &[u8]) -> Result<Vec<u8>> {
    if dense.len() != info.size() {
        return Err(Error::Tensor(format!(
            "dense payload {} != info size {}",
            dense.len(),
            info.size()
        )));
    }
    let esz = info.dtype.size();
    let n = info.count();
    let mut idx: Vec<u32> = Vec::new();
    for i in 0..n {
        let slot = &dense[i * esz..(i + 1) * esz];
        if slot.iter().any(|&b| b != 0) {
            idx.push(i as u32);
        }
    }
    let mut out = Vec::with_capacity(HEADER + idx.len() * (4 + esz));
    out.extend_from_slice(SPARSE_MAGIC);
    out.push(info.dtype as u8);
    out.push(MAX_RANK as u8);
    out.extend_from_slice(&[0, 0]);
    for d in info.dims {
        out.extend_from_slice(&d.to_le_bytes());
    }
    out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
    for &i in &idx {
        out.extend_from_slice(&i.to_le_bytes());
    }
    for &i in &idx {
        let i = i as usize;
        out.extend_from_slice(&dense[i * esz..(i + 1) * esz]);
    }
    Ok(out)
}

/// Decode a COO tensor back to (info, dense payload).
pub fn decode(buf: &[u8]) -> Result<(TensorInfo, Vec<u8>)> {
    if buf.len() < HEADER || &buf[..4] != SPARSE_MAGIC {
        return Err(Error::Tensor("not a sparse tensor (bad magic)".into()));
    }
    let dtype = DType::from_wire(buf[4])?;
    let mut dims = [1u32; MAX_RANK];
    for (j, d) in dims.iter_mut().enumerate() {
        *d = read_u32(buf, 8 + j * 4)?;
    }
    let info = TensorInfo::new(dtype, &dims)?;
    let nnz = read_u32(buf, 24)? as usize;
    let esz = dtype.size();
    let idx_end = HEADER + nnz * 4;
    let val_end = idx_end + nnz * esz;
    if buf.len() != val_end {
        return Err(Error::Tensor(format!(
            "sparse tensor length {} != expected {val_end}",
            buf.len()
        )));
    }
    let count = info.count();
    let mut dense = vec![0u8; info.size()];
    let mut prev: Option<u32> = None;
    for k in 0..nnz {
        let i = read_u32(buf, HEADER + k * 4)?;
        if i as usize >= count {
            return Err(Error::Tensor(format!("sparse index {i} out of {count}")));
        }
        if let Some(p) = prev {
            if i <= p {
                return Err(Error::Tensor("sparse indices not ascending".into()));
            }
        }
        prev = Some(i);
        let src = idx_end + k * esz;
        dense[i as usize * esz..(i as usize + 1) * esz].copy_from_slice(&buf[src..src + esz]);
    }
    Ok((info, dense))
}

/// Total encoded length of the sparse tensor at the start of `buf`
/// (supports concatenated multi-tensor sparse frames).
pub fn encoded_len(buf: &[u8]) -> Result<usize> {
    if buf.len() < HEADER || &buf[..4] != SPARSE_MAGIC {
        return Err(Error::Tensor("not a sparse tensor (bad magic)".into()));
    }
    let dtype = DType::from_wire(buf[4])?;
    let nnz = read_u32(buf, 24)? as usize;
    Ok(HEADER + nnz * (4 + dtype.size()))
}

/// Decode the sparse tensor at the start of `buf`, ignoring trailing
/// bytes (use [`encoded_len`] to advance).
pub fn decode_prefix(buf: &[u8]) -> Result<(TensorInfo, Vec<u8>)> {
    let len = encoded_len(buf)?;
    if buf.len() < len {
        return Err(Error::Tensor("sparse tensor truncated".into()));
    }
    decode(&buf[..len])
}

/// Size of the encoded form for a given nnz (for bench reporting).
pub fn encoded_size(info: &TensorInfo, nnz: usize) -> usize {
    HEADER + nnz * (4 + info.dtype.size())
}

/// Density below which COO is smaller than dense for this dtype.
pub fn breakeven_density(dtype: DType) -> f64 {
    // dense = n*esz; coo ≈ n*density*(4+esz) + HEADER
    dtype.size() as f64 / (4.0 + dtype.size() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f32_payload(vals: &[f32]) -> Vec<u8> {
        vals.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    #[test]
    fn roundtrip_sparse_f32() {
        let info = TensorInfo::new(DType::F32, &[8]).unwrap();
        let dense = f32_payload(&[0.0, 1.5, 0.0, 0.0, -2.0, 0.0, 0.0, 3.0]);
        let enc = encode(&info, &dense).unwrap();
        let (info2, dense2) = decode(&enc).unwrap();
        assert_eq!(info2.dims, info.dims);
        assert_eq!(dense2, dense);
    }

    #[test]
    fn all_zero_encodes_compactly() {
        let info = TensorInfo::new(DType::F32, &[100]).unwrap();
        let dense = vec![0u8; info.size()];
        let enc = encode(&info, &dense).unwrap();
        assert_eq!(enc.len(), HEADER);
        let (_, dense2) = decode(&enc).unwrap();
        assert_eq!(dense2, dense);
    }

    #[test]
    fn dense_tensor_grows_but_roundtrips() {
        let info = TensorInfo::new(DType::U8, &[16]).unwrap();
        let dense: Vec<u8> = (1..=16).collect();
        let enc = encode(&info, &dense).unwrap();
        assert!(enc.len() > dense.len()); // COO overhead on dense data
        assert_eq!(decode(&enc).unwrap().1, dense);
    }

    #[test]
    fn sparse_saves_space_below_breakeven() {
        let info = TensorInfo::new(DType::F32, &[1000]).unwrap();
        let mut vals = vec![0f32; 1000];
        for i in (0..1000).step_by(50) {
            vals[i] = 1.0; // 2% density << breakeven 0.5
        }
        let enc = encode(&info, &f32_payload(&vals)).unwrap();
        assert!(enc.len() < info.size() / 5, "{} vs {}", enc.len(), info.size());
    }

    #[test]
    fn rejects_wrong_payload_size() {
        let info = TensorInfo::new(DType::F32, &[4]).unwrap();
        assert!(encode(&info, &[0u8; 3]).is_err());
    }

    #[test]
    fn rejects_corrupt_length() {
        let info = TensorInfo::new(DType::F32, &[4]).unwrap();
        let mut enc = encode(&info, &f32_payload(&[1.0, 0.0, 2.0, 0.0])).unwrap();
        enc.pop();
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn rejects_out_of_range_index() {
        let info = TensorInfo::new(DType::U8, &[4]).unwrap();
        let mut enc = encode(&info, &[0, 9, 0, 0]).unwrap();
        // index entry for the single nnz lives right after the header
        enc[HEADER] = 200;
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn rejects_non_ascending_indices() {
        let info = TensorInfo::new(DType::U8, &[4]).unwrap();
        let mut enc = encode(&info, &[0, 1, 2, 0]).unwrap();
        // two nnz at idx 1,2 -> swap them
        enc[HEADER] = 2;
        enc[HEADER + 4] = 1;
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn breakeven_math() {
        assert!((breakeven_density(DType::F32) - 0.5).abs() < 1e-9);
        assert!(breakeven_density(DType::U8) < breakeven_density(DType::F64));
    }

    #[test]
    fn encoded_size_matches_actual() {
        let info = TensorInfo::new(DType::F32, &[64]).unwrap();
        let mut vals = vec![0f32; 64];
        vals[3] = 1.0;
        vals[9] = 2.0;
        let enc = encode(&info, &f32_payload(&vals)).unwrap();
        assert_eq!(enc.len(), encoded_size(&info, 2));
    }
}
