//! Fault-injecting TCP proxy for resilience tests.
//!
//! Sits between a query client and a real server (or nothing at all) and
//! misbehaves on command, so every branch of the offload resilience
//! policy — breaker transitions, backoff, deadline drops, hedging — can
//! be exercised deterministically:
//!
//! ```ignore
//! let proxy = FaultProxy::start(&server_addr)?;   // forwards by default
//! proxy.set(Fault::BlackHole);                    // accept, read, never reply
//! proxy.set(Fault::Delay(Duration::from_millis(200))); // slow-loris
//! proxy.rst_all();                                // RST every live conn
//! proxy.set(Fault::Deny);                         // refuse new conns
//! ```
//!
//! The fault mode is sampled per I/O pump iteration, so flipping it
//! mid-stream affects connections that are already established —
//! exactly what a hang or a sudden overload looks like from the client.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::Result;

/// What the proxy does with traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Forward bytes both ways (healthy).
    Pass,
    /// Refuse new connections (accepted then immediately RST-closed;
    /// from the client this is indistinguishable from a dead peer).
    Deny,
    /// Accept and read, but never forward upstream — the client's read
    /// blocks until its own timeout (a hung peer).
    BlackHole,
    /// Forward, but hold every chunk for this long first (a slow peer —
    /// inflates observed RTT without failing anything).
    Delay(Duration),
}

/// A TCP proxy whose behavior is switchable at runtime.
pub struct FaultProxy {
    addr: String,
    mode: Arc<Mutex<Fault>>,
    accepted: Arc<AtomicU64>,
    live: Arc<Mutex<Vec<TcpStream>>>,
    stop: Arc<AtomicBool>,
    pumps: Arc<AtomicUsize>,
}

impl FaultProxy {
    /// Start a proxy on an ephemeral port forwarding to `upstream`.
    pub fn start(upstream: &str) -> Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let mode = Arc::new(Mutex::new(Fault::Pass));
        let accepted = Arc::new(AtomicU64::new(0));
        let live: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let pumps = Arc::new(AtomicUsize::new(0));

        let up = upstream.to_string();
        let (m, a, l, s, p) =
            (mode.clone(), accepted.clone(), live.clone(), stop.clone(), pumps.clone());
        std::thread::Builder::new()
            .name("fault-proxy-accept".into())
            .spawn(move || {
                while !s.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((client, _)) => {
                            a.fetch_add(1, Ordering::Relaxed);
                            if *m.lock().unwrap() == Fault::Deny {
                                // Linger 0 -> RST on drop, like a closed port.
                                let _ = client.shutdown(Shutdown::Both);
                                continue;
                            }
                            let Ok(server) = TcpStream::connect(&up) else {
                                let _ = client.shutdown(Shutdown::Both);
                                continue;
                            };
                            client.set_nodelay(true).ok();
                            server.set_nodelay(true).ok();
                            for (mut from, mut to) in [
                                (client.try_clone(), server.try_clone()),
                                (server.try_clone(), client.try_clone()),
                            ]
                            .into_iter()
                            .filter_map(|(f, t)| f.ok().zip(t.ok()))
                            {
                                if let Ok(c) = from.try_clone() {
                                    l.lock().unwrap().push(c);
                                }
                                let (m2, s2, p2) = (m.clone(), s.clone(), p.clone());
                                p.fetch_add(1, Ordering::Relaxed);
                                std::thread::Builder::new()
                                    .name("fault-proxy-pump".into())
                                    .spawn(move || {
                                        pump(&mut from, &mut to, &m2, &s2);
                                        p2.fetch_sub(1, Ordering::Relaxed);
                                    })
                                    .ok();
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .map_err(|e| crate::util::Error::Transport(format!("spawn proxy: {e}")))?;

        Ok(Self { addr, mode, accepted, live, stop, pumps })
    }

    /// Address clients should connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Switch the fault mode; affects new traffic immediately, including
    /// established connections (their pumps sample the mode per chunk).
    pub fn set(&self, f: Fault) {
        *self.mode.lock().unwrap() = f;
    }

    /// Connections accepted so far (Deny'd ones included) — lets tests
    /// assert on reconnect-attempt counts (backoff pacing).
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    /// Hard-reset every live proxied connection (mid-stream RST from the
    /// client's point of view).
    pub fn rst_all(&self) {
        let mut live = self.live.lock().unwrap();
        for c in live.drain(..) {
            let _ = c.shutdown(Shutdown::Both);
        }
    }

    /// Live pump threads (0 once all proxied conns are torn down).
    pub fn pump_count(&self) -> usize {
        self.pumps.load(Ordering::Relaxed)
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.rst_all();
    }
}

/// One-directional byte pump, fault mode sampled per chunk. Read timeout
/// keeps the thread responsive to `stop` even while black-holed.
fn pump(from: &mut TcpStream, to: &mut TcpStream, mode: &Mutex<Fault>, stop: &AtomicBool) {
    let _ = from.set_read_timeout(Some(Duration::from_millis(100)));
    let mut buf = [0u8; 16 * 1024];
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let n = match from.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => break,
        };
        match *mode.lock().unwrap() {
            Fault::Pass => {}
            Fault::Deny => {} // only affects new connections
            Fault::BlackHole => continue, // swallow the chunk
            Fault::Delay(d) => std::thread::sleep(d),
        }
        if to.write_all(&buf[..n]).is_err() {
            break;
        }
    }
    let _ = to.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    /// Echo server for proxy tests.
    fn echo_server() -> String {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for conn in l.incoming() {
                let Ok(mut c) = conn else { break };
                std::thread::spawn(move || {
                    let mut buf = [0u8; 1024];
                    while let Ok(n) = c.read(&mut buf) {
                        if n == 0 || c.write_all(&buf[..n]).is_err() {
                            break;
                        }
                    }
                });
            }
        });
        addr
    }

    #[test]
    fn pass_forwards_both_ways() {
        let proxy = FaultProxy::start(&echo_server()).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.write_all(b"ping").unwrap();
        let mut out = [0u8; 4];
        c.read_exact(&mut out).unwrap();
        assert_eq!(&out, b"ping");
        assert_eq!(proxy.accepted(), 1);
    }

    #[test]
    fn deny_refuses_new_connections() {
        let proxy = FaultProxy::start(&echo_server()).unwrap();
        proxy.set(Fault::Deny);
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        // Either the write or the read must fail: the conn was closed
        // without ever reaching the upstream.
        let dead = c.write_all(b"ping").is_err() || c.read(&mut [0u8; 4]).map(|n| n == 0).unwrap_or(true);
        assert!(dead);
        assert_eq!(proxy.accepted(), 1);
    }

    #[test]
    fn black_hole_swallows_and_delay_slows() {
        let proxy = FaultProxy::start(&echo_server()).unwrap();
        proxy.set(Fault::BlackHole);
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        c.write_all(b"ping").unwrap();
        assert!(c.read(&mut [0u8; 4]).is_err(), "black hole must not answer");

        proxy.set(Fault::Delay(Duration::from_millis(150)));
        let mut c2 = TcpStream::connect(proxy.addr()).unwrap();
        let t0 = std::time::Instant::now();
        c2.write_all(b"pong").unwrap();
        let mut out = [0u8; 4];
        c2.read_exact(&mut out).unwrap();
        assert_eq!(&out, b"pong");
        assert!(t0.elapsed() >= Duration::from_millis(140), "delay not applied");
    }

    #[test]
    fn rst_all_kills_established_conns() {
        let proxy = FaultProxy::start(&echo_server()).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.write_all(b"ping").unwrap();
        let mut out = [0u8; 4];
        c.read_exact(&mut out).unwrap();
        proxy.rst_all();
        c.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let gone = matches!(c.read(&mut [0u8; 4]), Ok(0) | Err(_));
        assert!(gone, "connection should be dead after rst_all");
    }
}
