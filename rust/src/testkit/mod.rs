//! Minimal property-testing kit (proptest analog; no external crates
//! offline). Deterministic xorshift generation + shrink-by-halving for
//! numeric/vector inputs, with failing-seed reporting.
//!
//! ```ignore
//! testkit::check(200, |g| {
//!     let v = g.vec_u8(0..512);
//!     let enc = encode(&v);
//!     assert_eq!(decode(&enc).unwrap(), v);
//! });
//! ```

pub mod fault;

use crate::util::rng::XorShift64;

/// Value generator handed to a property closure.
pub struct Gen {
    rng: XorShift64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: XorShift64::new(seed), seed }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range(lo, hi)
    }

    pub fn u32(&mut self, lo: u32, hi: u32) -> u32 {
        self.rng.range(lo as u64, hi as u64) as u32
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as u64, hi as u64) as usize
    }

    pub fn i64(&mut self) -> i64 {
        self.rng.next_u64() as i64
    }

    pub fn f32(&mut self) -> f32 {
        self.rng.normal()
    }

    pub fn f32_unit(&mut self) -> f32 {
        self.rng.f32()
    }

    pub fn bool(&mut self, p: f32) -> bool {
        self.rng.bool(p)
    }

    pub fn vec_u8(&mut self, max_len: usize) -> Vec<u8> {
        let n = self.usize(0, max_len);
        let mut v = vec![0u8; n];
        self.rng.fill_bytes(&mut v);
        v
    }

    pub fn vec_f32(&mut self, max_len: usize) -> Vec<f32> {
        let n = self.usize(0, max_len);
        (0..n).map(|_| self.rng.normal()).collect()
    }

    pub fn ascii_string(&mut self, max_len: usize) -> String {
        let n = self.usize(0, max_len);
        (0..n).map(|_| (b'a' + (self.rng.below(26) as u8)) as char).collect()
    }

    /// Pick one of the provided items.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }
}

/// Run `prop` against `cases` generated inputs. Panics (with the seed)
/// on the first failing case so it can be replayed with [`check_seed`].
pub fn check(cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base = std::env::var("EDGEPIPE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_0000u64);
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        });
        if let Err(e) = result {
            eprintln!("testkit: property failed at case {i}, seed {seed:#x}");
            eprintln!("replay with EDGEPIPE_PROP_SEED={seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single seed.
pub fn check_seed(seed: u64, prop: impl Fn(&mut Gen)) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_bounds() {
        check(100, |g| {
            let n = g.usize(3, 9);
            assert!((3..=9).contains(&n));
            let v = g.vec_u8(16);
            assert!(v.len() <= 16);
            let s = g.ascii_string(5);
            assert!(s.len() <= 5 && s.chars().all(|c| c.is_ascii_lowercase()));
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        assert_eq!(a.vec_u8(100), b.vec_u8(100));
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check(10, |g| {
            let v = g.u64(0, 100);
            assert!(v < 101); // passes
            assert!(v < 5, "forced failure for {v}"); // eventually fails
        });
    }
}
