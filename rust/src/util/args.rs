//! Tiny CLI argument parser (no `clap` offline): `--key value`,
//! `--key=value`, `--flag`, and positionals, with typed getters.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse a raw argv tail (without the program name / subcommand).
    /// An option consumes the next token as its value unless it contains
    /// `=` or the next token starts with `--`.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    match iter.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.options.insert(stripped.to_string(), v);
                        }
                        _ => out.flags.push(stripped.to_string()),
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = parse(&["--broker", "127.0.0.1:1883", "--secs=5"]);
        assert_eq!(a.get("broker"), Some("127.0.0.1:1883"));
        assert_eq!(a.get_u64("secs", 0), 5);
    }

    #[test]
    fn parses_flags_and_positionals() {
        // A bare `--flag` must be last or followed by another option;
        // otherwise the next token is consumed as its value.
        let a = parse(&["run", "desc ! here", "--verbose"]);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["run", "desc ! here"]);
    }

    #[test]
    fn flag_before_option_not_consumed() {
        let a = parse(&["--quiet", "--secs", "9"]);
        assert!(a.has_flag("quiet"));
        assert_eq!(a.get_u64("secs", 0), 9);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_u64("n", 3), 3);
        assert_eq!(a.get_f64("f", 0.5), 0.5);
    }

    #[test]
    fn equals_form_with_spaces_in_value() {
        let a = parse(&["--desc=videotestsrc ! fakesink"]);
        assert_eq!(a.get("desc"), Some("videotestsrc ! fakesink"));
    }
}
