//! Minimal leveled logger (no `log`-crate consumers offline need more).
//!
//! Controlled by `EDGEPIPE_LOG` (error|warn|info|debug|trace), default warn.
//! All output goes to stderr so pipeline stdout stays machine-readable.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn env_level() -> u8 {
    match std::env::var("EDGEPIPE_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("info") => 2,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 1,
    }
}

pub fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let v = env_level();
    LEVEL.store(v, Ordering::Relaxed);
    v
}

/// Override the level programmatically (tests, CLI `-v`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if (l as u8) > level() {
        return;
    }
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match l {
        Level::Error => "E",
        Level::Warn => "W",
        Level::Info => "I",
        Level::Debug => "D",
        Level::Trace => "T",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{}.{:03} {tag} {target}] {msg}", t.as_secs() % 100_000, t.subsec_millis());
}

#[macro_export]
macro_rules! log_error { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($t:expr, $($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Trace, $t, format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_level_overrides() {
        set_level(Level::Debug);
        assert_eq!(level(), 3);
        set_level(Level::Warn);
        assert_eq!(level(), 1);
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Error);
        crate::log_warn!("test", "suppressed {}", 1);
        crate::log_error!("test", "printed {}", 2);
    }
}
