//! Small shared utilities: errors, PRNG, logging, byte helpers.

pub mod args;
pub mod log;
pub mod rng;

use std::fmt;

/// Crate-wide error type. Variants map to the subsystems a pipeline
/// developer sees in bus messages.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("caps negotiation failed: {0}")]
    Caps(String),
    #[error("tensor format error: {0}")]
    Tensor(String),
    #[error("serialization error: {0}")]
    Serial(String),
    #[error("mqtt protocol error: {0}")]
    Mqtt(String),
    #[error("transport error: {0}")]
    Transport(String),
    #[error("pipeline error: {0}")]
    Pipeline(String),
    #[error("parse error: {0}")]
    Parse(String),
    #[error("element `{element}`: {message}")]
    Element { element: String, message: String },
    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn element(element: impl Into<String>, message: impl fmt::Display) -> Self {
        Error::Element { element: element.into(), message: message.to_string() }
    }
}

/// Read a little-endian u32 from a byte slice at `off`.
pub fn read_u32(buf: &[u8], off: usize) -> Result<u32> {
    let b = buf
        .get(off..off + 4)
        .ok_or_else(|| Error::Serial(format!("short read at {off} (len {})", buf.len())))?;
    Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

/// Read a little-endian u64 from a byte slice at `off`.
pub fn read_u64(buf: &[u8], off: usize) -> Result<u64> {
    let b = buf
        .get(off..off + 8)
        .ok_or_else(|| Error::Serial(format!("short read at {off} (len {})", buf.len())))?;
    Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
}

/// Write several buffers with one vectored syscall where possible
/// (scatter-gather transport writes: frame header + shared payload leave
/// userspace without ever being assembled into one contiguous buffer).
///
/// Handles partial vectored writes by finishing each part with
/// `write_all`; equivalent to the unstable `Write::write_all_vectored`.
pub fn write_all_vectored<W: std::io::Write>(w: &mut W, parts: &[&[u8]]) -> std::io::Result<()> {
    use std::io::{ErrorKind, IoSlice};
    let total: usize = parts.iter().map(|p| p.len()).sum();
    if total == 0 {
        return Ok(());
    }
    let slices: Vec<IoSlice<'_>> = parts.iter().map(|p| IoSlice::new(p)).collect();
    let mut written = match w.write_vectored(&slices) {
        Ok(n) => n,
        Err(e) if e.kind() == ErrorKind::Interrupted => 0,
        Err(e) => return Err(e),
    };
    if written == total {
        return Ok(());
    }
    // Partial write (or a writer that ignores vectoring): finish each part.
    for part in parts {
        if written >= part.len() {
            written -= part.len();
            continue;
        }
        w.write_all(&part[written..])?;
        written = 0;
    }
    Ok(())
}

/// Human-readable byte size (for metrics reports).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_u32_le() {
        assert_eq!(read_u32(&[1, 0, 0, 0, 9], 0).unwrap(), 1);
        assert_eq!(read_u32(&[0, 1, 0, 0, 0], 1).unwrap(), 1);
    }

    #[test]
    fn read_u32_short_errors() {
        assert!(read_u32(&[1, 2, 3], 0).is_err());
        assert!(read_u32(&[1, 2, 3, 4], 1).is_err());
    }

    #[test]
    fn read_u64_le() {
        let mut b = [0u8; 8];
        b[0] = 0xff;
        assert_eq!(read_u64(&b, 0).unwrap(), 255);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(10), "10 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn write_all_vectored_concatenates_parts() {
        let mut out = Vec::new();
        write_all_vectored(&mut out, &[b"ab", b"", b"cde", b"f"]).unwrap();
        assert_eq!(out, b"abcdef");
        write_all_vectored(&mut out, &[]).unwrap();
        assert_eq!(out, b"abcdef");
    }

    #[test]
    fn write_all_vectored_survives_partial_writers() {
        /// Writer that accepts at most one byte per call.
        struct Trickle(Vec<u8>);
        impl std::io::Write for Trickle {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                if buf.is_empty() {
                    return Ok(0);
                }
                self.0.push(buf[0]);
                Ok(1)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut w = Trickle(Vec::new());
        write_all_vectored(&mut w, &[b"xy", b"z", b"12"]).unwrap();
        assert_eq!(w.0, b"xyz12");
    }

    #[test]
    fn element_error_formats() {
        let e = Error::element("q0", "full");
        assert_eq!(e.to_string(), "element `q0`: full");
    }
}
