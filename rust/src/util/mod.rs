//! Small shared utilities: errors, PRNG, logging, byte helpers.

pub mod args;
pub mod log;
pub mod rng;

use std::fmt;

/// Crate-wide error type. Variants map to the subsystems a pipeline
/// developer sees in bus messages.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("caps negotiation failed: {0}")]
    Caps(String),
    #[error("tensor format error: {0}")]
    Tensor(String),
    #[error("serialization error: {0}")]
    Serial(String),
    #[error("mqtt protocol error: {0}")]
    Mqtt(String),
    #[error("transport error: {0}")]
    Transport(String),
    #[error("pipeline error: {0}")]
    Pipeline(String),
    #[error("parse error: {0}")]
    Parse(String),
    #[error("element `{element}`: {message}")]
    Element { element: String, message: String },
    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn element(element: impl Into<String>, message: impl fmt::Display) -> Self {
        Error::Element { element: element.into(), message: message.to_string() }
    }
}

/// Read a little-endian u32 from a byte slice at `off`.
pub fn read_u32(buf: &[u8], off: usize) -> Result<u32> {
    let b = buf
        .get(off..off + 4)
        .ok_or_else(|| Error::Serial(format!("short read at {off} (len {})", buf.len())))?;
    Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

/// Read a little-endian u64 from a byte slice at `off`.
pub fn read_u64(buf: &[u8], off: usize) -> Result<u64> {
    let b = buf
        .get(off..off + 8)
        .ok_or_else(|| Error::Serial(format!("short read at {off} (len {})", buf.len())))?;
    Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
}

/// Human-readable byte size (for metrics reports).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_u32_le() {
        assert_eq!(read_u32(&[1, 0, 0, 0, 9], 0).unwrap(), 1);
        assert_eq!(read_u32(&[0, 1, 0, 0, 0], 1).unwrap(), 1);
    }

    #[test]
    fn read_u32_short_errors() {
        assert!(read_u32(&[1, 2, 3], 0).is_err());
        assert!(read_u32(&[1, 2, 3, 4], 1).is_err());
    }

    #[test]
    fn read_u64_le() {
        let mut b = [0u8; 8];
        b[0] = 0xff;
        assert_eq!(read_u64(&b, 0).unwrap(), 255);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(10), "10 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn element_error_formats() {
        let e = Error::element("q0", "full");
        assert_eq!(e.to_string(), "element `q0`: full");
    }
}
