//! Deterministic xorshift64* PRNG — no external `rand` crate offline.
//!
//! Used by the synthetic workload generators (videotestsrc noise mode,
//! sparse-tensor benches) and the in-repo property-testing kit.

/// xorshift64* generator. Deterministic for a given seed; NOT crypto.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // 0 is a fixed point of xorshift; displace it.
        Self { state: if seed == 0 { 0x9e37_79b9_7f4a_7c15 } else { seed } }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Standard-normal-ish via sum of uniforms (Irwin–Hall, 12 terms).
    pub fn normal(&mut self) -> f32 {
        let mut s = 0.0f32;
        for _ in 0..12 {
            s += self.f32();
        }
        s - 6.0
    }

    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        for chunk in out.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    pub fn bool(&mut self, p: f32) -> bool {
        self.f32() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zero_seed_not_stuck() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
        assert_ne!(r.next_u64(), r.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = XorShift64::new(3);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = XorShift64::new(4);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = XorShift64::new(5);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_roughly_centered() {
        let mut r = XorShift64::new(6);
        let mean: f32 = (0..4000).map(|_| r.normal()).sum::<f32>() / 4000.0;
        assert!(mean.abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = XorShift64::new(8);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
