//! ZeroMQ-style brokerless PUB/SUB over TCP — the *baseline* transport of
//! the paper's evaluation (§5.4, Fig 7 normalizes MQTT by ZeroMQ).
//!
//! Semantics follow zmq PUB/SUB: the publisher binds, subscribers connect
//! and upload prefix subscriptions, filtering happens publisher-side, slow
//! subscribers drop messages (no backpressure onto the publisher). Wire
//! format is two length-prefixed frames per message: topic, payload.
//!
//! The data path is zero-copy: [`PubSocket::send_parts`] fans a shared
//! payload (e.g. a [`crate::serial::wire::WireFrame`]'s header + payload)
//! out to every subscriber without duplication, and [`SubSocket::recv`]
//! returns the payload as a [`Bytes`] — the receive hop's single
//! allocation.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::buffer::Bytes;
use crate::util::{write_all_vectored, Error, Result};
use crate::{log_debug, log_info};

const SUB_CMD: u8 = 1;
const UNSUB_CMD: u8 = 2;
const MSG_CMD: u8 = 3;

/// One queued outbound message: shared topic + up to two shared payload
/// parts (scatter-gather; part order is preserved on the wire).
struct OutMsg {
    topic: Bytes,
    parts: [Bytes; 2],
}

fn write_chunk(w: &mut impl Write, cmd: u8, a: &[u8], b: &[u8]) -> std::io::Result<()> {
    w.write_all(&[cmd])?;
    w.write_all(&(a.len() as u32).to_le_bytes())?;
    w.write_all(a)?;
    w.write_all(&(b.len() as u32).to_le_bytes())?;
    w.write_all(b)?;
    Ok(())
}

/// Vectored emit of one PUB message: command byte, topic, and both
/// payload parts leave in a single scatter-gather write — the shared
/// payload is never assembled into a contiguous buffer.
fn write_msg(w: &mut impl Write, msg: &OutMsg) -> std::io::Result<()> {
    let body_len = msg.parts[0].len() + msg.parts[1].len();
    let mut head = Vec::with_capacity(1 + 4 + msg.topic.len() + 4);
    head.push(MSG_CMD);
    head.extend_from_slice(&(msg.topic.len() as u32).to_le_bytes());
    head.extend_from_slice(&msg.topic);
    head.extend_from_slice(&(body_len as u32).to_le_bytes());
    write_all_vectored(
        w,
        &[head.as_slice(), msg.parts[0].as_slice(), msg.parts[1].as_slice()],
    )
}

fn read_exact_vec(r: &mut impl Read, limit: usize) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > limit {
        return Err(Error::Transport(format!("zmq frame {n} exceeds limit")));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

struct SubConn {
    outbox: SyncSender<OutMsg>,
    prefixes: Vec<Vec<u8>>,
}

#[derive(Debug, Default, Clone)]
pub struct PubStats {
    pub sent: u64,
    pub dropped_slow: u64,
    pub subscribers: usize,
}

/// PUB socket: bind, then `send(topic, payload)`.
pub struct PubSocket {
    addr: SocketAddr,
    conns: Arc<Mutex<HashMap<u64, SubConn>>>,
    shutdown: Arc<AtomicBool>,
    stats_sent: Arc<AtomicU64>,
    stats_dropped: Arc<AtomicU64>,
}

impl PubSocket {
    pub fn bind(bind: &str) -> Result<PubSocket> {
        PubSocket::bind_with_depth(bind, 16)
    }

    /// `depth`: per-subscriber outbound queue (zmq HWM analog).
    pub fn bind_with_depth(bind: &str, depth: usize) -> Result<PubSocket> {
        let listener =
            TcpListener::bind(bind).map_err(|e| Error::Transport(format!("bind {bind}: {e}")))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let conns: Arc<Mutex<HashMap<u64, SubConn>>> = Arc::new(Mutex::new(HashMap::new()));
        let shutdown = Arc::new(AtomicBool::new(false));

        let a_conns = conns.clone();
        let a_shutdown = shutdown.clone();
        std::thread::Builder::new()
            .name("zmq-pub-accept".into())
            .spawn(move || {
                log_info!("zmq.pub", "listening on {addr}");
                let mut next_id = 1u64;
                while !a_shutdown.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            stream.set_nodelay(true).ok();
                            let id = next_id;
                            next_id += 1;
                            let (tx, rx) = sync_channel::<OutMsg>(depth);
                            a_conns
                                .lock()
                                .unwrap()
                                .insert(id, SubConn { outbox: tx, prefixes: Vec::new() });
                            spawn_sub_threads(id, stream, rx, a_conns.clone(), a_shutdown.clone());
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn zmq accept");
        Ok(PubSocket {
            addr,
            conns,
            shutdown,
            stats_sent: Arc::new(AtomicU64::new(0)),
            stats_dropped: Arc::new(AtomicU64::new(0)),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Publish a borrowed payload (copied once into a shared allocation,
    /// then fanned out copy-free).
    pub fn send(&self, topic: &[u8], payload: &[u8]) {
        self.send_parts(topic, [Bytes::copy_from_slice(payload), Bytes::new()]);
    }

    /// Publish an encoded [`crate::serial::wire::WireFrame`]: the frame's
    /// header and payload fan out as shared parts. For a compressed frame
    /// both parts are views into ONE allocation, deflated exactly once by
    /// the encoder regardless of subscriber count.
    pub fn send_frame(&self, topic: &[u8], frame: &crate::serial::wire::WireFrame) {
        self.send_parts(topic, [frame.header.clone(), frame.payload.clone()]);
    }

    /// Publish shared payload parts to all subscribers whose prefix
    /// matches `topic` — the parts are concatenated on the wire and never
    /// duplicated per subscriber.
    pub fn send_parts(&self, topic: &[u8], parts: [Bytes; 2]) {
        let t = Bytes::copy_from_slice(topic);
        let conns = self.conns.lock().unwrap();
        for c in conns.values() {
            if c.prefixes.iter().any(|pre| topic.starts_with(pre.as_slice())) {
                let msg = OutMsg { topic: t.clone(), parts: [parts[0].clone(), parts[1].clone()] };
                match c.outbox.try_send(msg) {
                    Ok(()) => {
                        self.stats_sent.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(TrySendError::Full(_)) => {
                        self.stats_dropped.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(TrySendError::Disconnected(_)) => {}
                }
            }
        }
    }

    pub fn subscriber_count(&self) -> usize {
        self.conns.lock().unwrap().len()
    }

    pub fn stats(&self) -> PubStats {
        PubStats {
            sent: self.stats_sent.load(Ordering::Relaxed),
            dropped_slow: self.stats_dropped.load(Ordering::Relaxed),
            subscribers: self.subscriber_count(),
        }
    }

    /// Wait until at least `n` subscribers have a matching prefix installed.
    pub fn wait_subscribers(&self, n: usize, timeout: Duration) -> bool {
        let start = std::time::Instant::now();
        while start.elapsed() < timeout {
            let conns = self.conns.lock().unwrap();
            if conns.values().filter(|c| !c.prefixes.is_empty()).count() >= n {
                return true;
            }
            drop(conns);
            std::thread::sleep(Duration::from_millis(5));
        }
        false
    }
}

impl Drop for PubSocket {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

fn spawn_sub_threads(
    id: u64,
    stream: TcpStream,
    rx: Receiver<OutMsg>,
    conns: Arc<Mutex<HashMap<u64, SubConn>>>,
    shutdown: Arc<AtomicBool>,
) {
    // Writer: drain the outbox to the socket.
    let mut wstream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    std::thread::Builder::new()
        .name(format!("zmq-pub-wr-{id}"))
        .spawn(move || {
            for msg in rx {
                if write_msg(&mut wstream, &msg).is_err() {
                    break;
                }
            }
            let _ = wstream.shutdown(std::net::Shutdown::Both);
        })
        .expect("spawn zmq writer");

    // Reader: subscription control frames.
    let mut rstream = stream;
    rstream.set_read_timeout(Some(Duration::from_millis(200))).ok();
    std::thread::Builder::new()
        .name(format!("zmq-pub-rd-{id}"))
        .spawn(move || {
            loop {
                if shutdown.load(Ordering::Relaxed) {
                    break;
                }
                let mut cmd = [0u8; 1];
                match rstream.read_exact(&mut cmd) {
                    Ok(()) => {}
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock
                            || e.kind() == std::io::ErrorKind::TimedOut =>
                    {
                        continue;
                    }
                    Err(_) => break,
                }
                let a = match read_exact_vec(&mut rstream, 1 << 20) {
                    Ok(v) => v,
                    Err(_) => break,
                };
                let _b = match read_exact_vec(&mut rstream, 1 << 20) {
                    Ok(v) => v,
                    Err(_) => break,
                };
                let mut cs = conns.lock().unwrap();
                let Some(c) = cs.get_mut(&id) else { break };
                match cmd[0] {
                    SUB_CMD => c.prefixes.push(a),
                    UNSUB_CMD => c.prefixes.retain(|p| p != &a),
                    _ => break,
                }
            }
            conns.lock().unwrap().remove(&id);
            log_debug!("zmq.pub", "subscriber {id} gone");
        })
        .expect("spawn zmq reader");
}

/// SUB socket: connect to a PUB, subscribe prefixes, receive messages.
pub struct SubSocket {
    stream: TcpStream,
}

/// A received (topic, payload) message. The payload is the receive hop's
/// single allocation, shared onward as a [`Bytes`].
pub type ZmqMessage = (Vec<u8>, Bytes);

impl SubSocket {
    pub fn connect(addr: &str) -> Result<SubSocket> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Transport(format!("zmq connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        Ok(SubSocket { stream })
    }

    /// Install a prefix subscription (empty prefix = everything).
    pub fn subscribe(&mut self, prefix: &[u8]) -> Result<()> {
        write_chunk(&mut self.stream, SUB_CMD, prefix, &[])?;
        Ok(())
    }

    pub fn unsubscribe(&mut self, prefix: &[u8]) -> Result<()> {
        write_chunk(&mut self.stream, UNSUB_CMD, prefix, &[])?;
        Ok(())
    }

    /// Blocking receive of the next message.
    pub fn recv(&mut self) -> Result<ZmqMessage> {
        let mut cmd = [0u8; 1];
        self.stream.read_exact(&mut cmd)?;
        if cmd[0] != MSG_CMD {
            return Err(Error::Transport(format!("unexpected zmq cmd {}", cmd[0])));
        }
        let topic = read_exact_vec(&mut self.stream, 1 << 20)?;
        let payload = read_exact_vec(&mut self.stream, 512 << 20)?;
        Ok((topic, Bytes::from(payload)))
    }

    pub fn set_timeout(&mut self, d: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(d)?;
        Ok(())
    }

    /// Spawn a reader thread delivering into a channel.
    pub fn into_channel(mut self, depth: usize) -> Receiver<ZmqMessage> {
        let (tx, rx) = sync_channel(depth);
        std::thread::Builder::new()
            .name("zmq-sub-reader".into())
            .spawn(move || {
                self.set_timeout(None).ok();
                while let Ok(msg) = self.recv() {
                    if tx.send(msg).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn zmq sub reader");
        rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pubsub_roundtrip() {
        let p = PubSocket::bind("127.0.0.1:0").unwrap();
        let mut s = SubSocket::connect(&p.addr().to_string()).unwrap();
        s.subscribe(b"cam").unwrap();
        assert!(p.wait_subscribers(1, Duration::from_secs(2)));
        p.send(b"camleft", b"frame");
        let (t, pl) = s.recv().unwrap();
        assert_eq!(t, b"camleft");
        assert_eq!(&pl[..], b"frame");
    }

    #[test]
    fn send_parts_concatenates_on_the_wire() {
        let p = PubSocket::bind("127.0.0.1:0").unwrap();
        let mut s = SubSocket::connect(&p.addr().to_string()).unwrap();
        s.subscribe(b"t").unwrap();
        assert!(p.wait_subscribers(1, Duration::from_secs(2)));
        p.send_parts(b"t", [Bytes::from(b"head-".to_vec()), Bytes::from(b"payload".to_vec())]);
        let (_, pl) = s.recv().unwrap();
        assert_eq!(&pl[..], b"head-payload");
    }

    #[test]
    fn prefix_filtering_is_publisher_side() {
        let p = PubSocket::bind("127.0.0.1:0").unwrap();
        let mut s = SubSocket::connect(&p.addr().to_string()).unwrap();
        s.subscribe(b"a/").unwrap();
        assert!(p.wait_subscribers(1, Duration::from_secs(2)));
        p.send(b"b/x", b"drop-me");
        p.send(b"a/x", b"keep-me");
        let (t, _) = s.recv().unwrap();
        assert_eq!(t, b"a/x");
        assert_eq!(p.stats().sent, 1); // the b/x send never left the pub
    }

    #[test]
    fn empty_prefix_matches_all() {
        let p = PubSocket::bind("127.0.0.1:0").unwrap();
        let mut s = SubSocket::connect(&p.addr().to_string()).unwrap();
        s.subscribe(b"").unwrap();
        assert!(p.wait_subscribers(1, Duration::from_secs(2)));
        p.send(b"anything", b"x");
        assert_eq!(s.recv().unwrap().0, b"anything");
    }

    #[test]
    fn multiple_subscribers_fan_out() {
        let p = PubSocket::bind("127.0.0.1:0").unwrap();
        let mut s1 = SubSocket::connect(&p.addr().to_string()).unwrap();
        let mut s2 = SubSocket::connect(&p.addr().to_string()).unwrap();
        s1.subscribe(b"t").unwrap();
        s2.subscribe(b"t").unwrap();
        assert!(p.wait_subscribers(2, Duration::from_secs(2)));
        p.send(b"t", b"x");
        assert_eq!(&s1.recv().unwrap().1[..], b"x");
        assert_eq!(&s2.recv().unwrap().1[..], b"x");
    }

    #[test]
    fn unsubscribe_stops_delivery() {
        let p = PubSocket::bind("127.0.0.1:0").unwrap();
        let mut s = SubSocket::connect(&p.addr().to_string()).unwrap();
        s.subscribe(b"t").unwrap();
        assert!(p.wait_subscribers(1, Duration::from_secs(2)));
        s.unsubscribe(b"t").unwrap();
        std::thread::sleep(Duration::from_millis(300)); // let unsub land
        p.send(b"t", b"x");
        s.set_timeout(Some(Duration::from_millis(200))).unwrap();
        assert!(s.recv().is_err());
    }

    #[test]
    fn slow_subscriber_drops_not_blocks() {
        let p = PubSocket::bind_with_depth("127.0.0.1:0", 2).unwrap();
        let mut s = SubSocket::connect(&p.addr().to_string()).unwrap();
        s.subscribe(b"t").unwrap();
        assert!(p.wait_subscribers(1, Duration::from_secs(2)));
        // Subscriber never reads; flood the publisher. Shared payload: the
        // 64 KiB frame is allocated once, not per send.
        let payload = Bytes::from(vec![0u8; 65536]);
        for _ in 0..2000 {
            p.send_parts(b"t", [payload.clone(), Bytes::new()]);
        }
        let st = p.stats();
        assert!(st.dropped_slow > 0, "expected drops, stats {st:?}");
    }

    #[test]
    fn channel_reader_mode() {
        let p = PubSocket::bind("127.0.0.1:0").unwrap();
        let mut s = SubSocket::connect(&p.addr().to_string()).unwrap();
        s.subscribe(b"c").unwrap();
        let rx = s.into_channel(16);
        assert!(p.wait_subscribers(1, Duration::from_secs(2)));
        p.send(b"c", b"via-channel");
        let (_, pl) = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(&pl[..], b"via-channel");
    }

    #[test]
    fn large_payload() {
        let p = PubSocket::bind("127.0.0.1:0").unwrap();
        let mut s = SubSocket::connect(&p.addr().to_string()).unwrap();
        s.subscribe(b"big").unwrap();
        assert!(p.wait_subscribers(1, Duration::from_secs(2)));
        let payload = vec![7u8; 6_220_800]; // one FullHD RGB frame
        p.send(b"big", &payload);
        let (_, pl) = s.recv().unwrap();
        assert_eq!(pl.len(), payload.len());
    }
}
