//! Integration: the paper's among-device scenarios end-to-end, with the
//! PJRT-backed models where artifacts are available.
//!
//! "Devices" are separate pipelines in one process; every byte still
//! crosses real TCP/UDP sockets through the in-repo broker/transports.

use std::time::Duration;

use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::elements::{appsink_channel, appsrc_channel};
use edgepipe::metrics;
use edgepipe::mqtt::Broker;
use edgepipe::pipeline::{parser, Running, WaitOutcome};
use edgepipe::tensor;

fn registry() -> Registry {
    Registry::with_builtins()
}

fn env() -> PipelineEnv {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    PipelineEnv { artifacts_dir: dir.to_string_lossy().into_owned() }
}

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/detect.manifest.txt").exists()
}

fn start(desc: &str) -> Running {
    parser::parse(desc, &registry(), &env()).expect("parse").start().expect("start")
}

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

// ---------------------------------------------------------------------------
// Listing 1 / Figure 2: workload offloading with query elements
// ---------------------------------------------------------------------------

#[test]
fn listing1_offload_detect_model_tcp() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let port = free_port();
    // Device B (Listing 1 server): one line of pipeline code + model.
    let server = start(&format!(
        "tensor_query_serversrc operation=detectgate port={port} pair-id=l1tcp ! \
         tensor_filter framework=pjrt model=detect ! \
         tensor_query_serversink operation=detectgate pair-id=l1tcp"
    ));
    std::thread::sleep(Duration::from_millis(300));
    // Device A (client): camera -> preprocess -> query -> sink.
    metrics::global().reset();
    let client = start(&format!(
        "videotestsrc width=96 height=96 num-buffers=8 is-live=false pattern=ball ! \
         videoconvert ! video/x-raw,width=96,height=96,format=RGB ! \
         queue leaky=2 ! tensor_converter ! \
         tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 ! \
         tensor_query_client operation=detectgate server=127.0.0.1:{port} ! \
         appsink name=l1out"
    ));
    assert_eq!(client.wait_eos(Duration::from_secs(120)), WaitOutcome::Eos);
    let c = metrics::global().counter("appsink.l1out");
    assert_eq!(c.count(), 8);
    assert_eq!(c.bytes(), 8 * 4); // detect model: one f32 activation per frame
    let _ = server.stop(Duration::from_secs(5));
}

#[test]
fn offload_with_mqtt_hybrid_discovery() {
    if !have_artifacts() {
        return;
    }
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let b = broker.addr().to_string();
    let port = free_port();
    let server = start(&format!(
        "tensor_query_serversrc operation=objdetect/detect port={port} pair-id=hyb1 \
           protocol=mqtt-hybrid broker={b} server-id=hyb-a model-label=detect-v1 ! \
         tensor_filter framework=pjrt model=detect ! \
         tensor_query_serversink operation=objdetect/detect pair-id=hyb1"
    ));
    std::thread::sleep(Duration::from_millis(400));
    metrics::global().reset();
    // Client discovers by capability (`objdetect/#`), not address (R3).
    let client = start(&format!(
        "videotestsrc width=96 height=96 num-buffers=5 is-live=false ! \
         tensor_converter ! tensor_transform mode=arithmetic option=typecast:float32,div:255.0 ! \
         tensor_query_client operation=objdetect/# protocol=mqtt-hybrid broker={b} ! \
         appsink name=hybout"
    ));
    assert_eq!(client.wait_eos(Duration::from_secs(120)), WaitOutcome::Eos);
    assert_eq!(metrics::global().counter("appsink.hybout").count(), 5);
    let _ = server.stop(Duration::from_secs(5));
}

// ---------------------------------------------------------------------------
// Listing 2 / Figure 3: pub/sub with two cameras, processing, output
// ---------------------------------------------------------------------------

#[test]
fn listing2_pubsub_two_cameras_processing_output() {
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let b = broker.addr().to_string();

    // Output device (Device D): subscribes both cameras, muxes, composites.
    let output = start(&format!(
        "mqttsrc sub-topic=camleft broker={b} ! tensor_converter ! queue ! mux.sink_0 \
         mqttsrc sub-topic=camright broker={b} ! tensor_converter ! queue ! mux.sink_1 \
         tensor_mux name=mux ! tensor_demux name=dmux srcs=2 \
         dmux.src_0 ! tensor_decoder mode=direct_video ! queue ! mix.sink_0 \
         dmux.src_1 ! tensor_decoder mode=direct_video ! queue ! mix.sink_1 \
         compositor name=mix sink_0::xpos=0 sink_1::xpos=32 ! videoconvert ! appsink name=display"
    ));
    std::thread::sleep(Duration::from_millis(300));

    // Camera devices (C1, C2) publish via flexbuf like Listing 2.
    let cam1 = start(&format!(
        "videotestsrc width=32 height=24 num-buffers=30 pattern=ball ! \
         tensor_converter ! tensor_decoder mode=flexbuf ! \
         mqttsink pub-topic=camleft broker={b}"
    ));
    let cam2 = start(&format!(
        "videotestsrc width=32 height=24 num-buffers=30 pattern=smpte ! \
         tensor_converter ! tensor_decoder mode=flexbuf ! \
         mqttsink pub-topic=camright broker={b}"
    ));
    // Wait: flexbuf -> mqtt -> tensor_converter on the output device.
    // Cameras are live 30fps: 30 frames ~ 1s.
    let _ = cam1.wait_eos(Duration::from_secs(30));
    let _ = cam2.wait_eos(Duration::from_secs(30));
    std::thread::sleep(Duration::from_millis(500));
    let c = metrics::global().counter("appsink.display");
    assert!(c.count() > 0, "no composited frames delivered");
    // Composite canvas is 64x24 RGB.
    let _ = output.stop(Duration::from_secs(5));
}

// ---------------------------------------------------------------------------
// §4.2.3: timestamp synchronization with injected latency
// ---------------------------------------------------------------------------

#[test]
fn timestamp_sync_rebases_remote_pts() {
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let b = broker.addr().to_string();
    let sub = start(&format!("mqttsrc sub-topic=ts/cam broker={b} ! tensor_converter ! appsink channel=tsout"));
    let rx = appsink_channel("tsout").unwrap();
    std::thread::sleep(Duration::from_millis(300));
    // Publisher starts LATER: its pts ~0 must map to a positive local pts
    // roughly equal to the subscriber's elapsed runtime.
    std::thread::sleep(Duration::from_millis(400));
    let publ = start(&format!(
        "videotestsrc width=8 height=8 num-buffers=5 ! tensor_converter ! \
         tensor_decoder mode=flexbuf ! mqttsink pub-topic=ts/cam broker={b}"
    ));
    let first = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    let pts = first.pts.expect("pts");
    assert!(
        pts > 300 * edgepipe::clock::MSECOND && pts < 30 * edgepipe::clock::SECOND,
        "rebased pts {pts}"
    );
    let _ = publ.wait_eos(Duration::from_secs(10));
    let _ = sub.stop(Duration::from_secs(5));
}

// ---------------------------------------------------------------------------
// Fig 5: multi-modal augmented worker (tensor_if gating)
// ---------------------------------------------------------------------------

#[test]
fn fig5_detect_gate_controls_wearable_stream() {
    if !have_artifacts() {
        return;
    }
    // DETECT model gates: activation > 0.5 -> "then" branch counts.
    metrics::global().reset();
    let running = start(
        "videotestsrc width=96 height=96 num-buffers=10 is-live=false pattern=ball ! \
         tensor_converter ! tensor_transform mode=arithmetic option=typecast:float32,div:255.0 ! \
         tensor_filter framework=pjrt model=detect ! tensor_if compared-value=0 operator=gt threshold=0.5 name=gate \
         gate.src_0 ! appsink name=active \
         gate.src_1 ! appsink name=idle",
    );
    assert_eq!(running.wait_eos(Duration::from_secs(120)), WaitOutcome::Eos);
    let active = metrics::global().counter("appsink.active").count();
    let idle = metrics::global().counter("appsink.idle").count();
    assert_eq!(active + idle, 10, "every frame routed exactly once");
}

// ---------------------------------------------------------------------------
// PJRT detector end-to-end (Listing 1's model on-device)
// ---------------------------------------------------------------------------

#[test]
fn detector_pipeline_decodes_bounding_boxes() {
    if !have_artifacts() {
        return;
    }
    let h = appsrc_channel("detin", 4);
    let registry = registry();
    let e = env();
    let p = parser::parse(
        "appsrc channel=detin ! \
         other/tensors,num_tensors=1,dimensions=3:300:300:1,types=float32 ! \
         tensor_filter framework=pjrt model=detector ! \
         tensor_decoder mode=bounding_boxes option4=64:48 ! appsink channel=detout",
        &registry,
        &e,
    )
    .unwrap();
    let rx = appsink_channel("detout").unwrap();
    let running = p.start().unwrap();
    let input = vec![0.1f32; 300 * 300 * 3];
    let mut info = edgepipe::tensor::TensorsInfo::default();
    info.push(edgepipe::tensor::TensorInfo::new(edgepipe::tensor::DType::F32, &[3, 300, 300]).unwrap())
        .unwrap();
    h.push_with_caps(
        edgepipe::caps::Caps::tensors(&info),
        edgepipe::buffer::Buffer::new(tensor::f32_to_bytes(&input)),
    )
    .unwrap();
    let frame = rx.recv_timeout(Duration::from_secs(180)).unwrap();
    assert_eq!(frame.len(), 64 * 48 * 3); // rendered RGB canvas
    drop(h);
    assert_eq!(running.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
}
