//! Cross-pipeline adaptive inference batching, end to end: M pipelines
//! share one `BatchCollector`; frames coalesce into multi-frame
//! `infer_batch` calls and demux back to the right pipeline in order,
//! with no added latency when there is nothing to coalesce (M=1) and no
//! corruption under leaky queues.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use edgepipe::buffer::{Buffer, Bytes};
use edgepipe::caps::Caps;
use edgepipe::element::{Ctx, Element, Item, Leaky};
use edgepipe::elements::{AppSink, AppSrc, AppSrcHandle, Queue, TensorFilter};
use edgepipe::pipeline::{ExecMode, Pipeline, WaitOutcome};
use edgepipe::runtime::{BatchCfg, BatchCollector, InferenceBackend};
use edgepipe::util::Result;

/// Echo backend that records every batch size it sees.
struct RecordingEcho {
    sizes: Arc<Mutex<Vec<usize>>>,
    /// Per-batch artificial inference cost.
    delay: Duration,
}

impl InferenceBackend for RecordingEcho {
    fn label(&self) -> &str {
        "recording-echo"
    }
    fn negotiate(&mut self, c: &Caps) -> Result<Caps> {
        Ok(c.clone())
    }
    fn infer_batch(&mut self, inputs: &[Bytes]) -> Result<Vec<Vec<u8>>> {
        self.sizes.lock().unwrap().push(inputs.len());
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(inputs.iter().map(|b| b.to_vec()).collect())
    }
}

fn echo_collector(
    label: &str,
    cfg: BatchCfg,
    delay: Duration,
) -> (Arc<BatchCollector>, Arc<Mutex<Vec<usize>>>) {
    let sizes = Arc::new(Mutex::new(Vec::new()));
    let backend = RecordingEcho { sizes: sizes.clone(), delay };
    (BatchCollector::new(label, Box::new(backend), cfg), sizes)
}

/// One AppSrc -> batched tensor_filter -> AppSink pipeline over a shared
/// collector. Returns the running pipeline, its feed handle, and the
/// sink receiver.
fn member_pipeline(
    collector: &Arc<BatchCollector>,
) -> (edgepipe::pipeline::Running, AppSrcHandle, std::sync::mpsc::Receiver<Buffer>) {
    let mut p = Pipeline::new();
    let (src, h) = AppSrc::new(8, Some(Caps::any()));
    let (sink, rx) = AppSink::new(64);
    let s = p.add("src", Box::new(src)).unwrap();
    let f = p.add("f", Box::new(TensorFilter::batched(collector.clone()))).unwrap();
    let k = p.add("k", Box::new(sink)).unwrap();
    p.link(s, f).unwrap();
    p.link(f, k).unwrap();
    (p.start_mode(ExecMode::Pool).unwrap(), h, rx)
}

#[test]
fn m8_pipelines_form_multi_frame_batches_with_exact_demux() {
    const M: usize = 8;
    const ROUNDS: u8 = 6;
    let (collector, sizes) = echo_collector(
        "t_m8",
        BatchCfg { max_batch: M, timeout: Duration::from_millis(2000) },
        Duration::ZERO,
    );
    let mut running = Vec::new();
    let mut feeds = Vec::new();
    let mut sinks = Vec::new();
    for _ in 0..M {
        let (r, h, rx) = member_pipeline(&collector);
        running.push(r);
        feeds.push(h);
        sinks.push(rx);
    }
    // Round-synchronized feeding: every pipeline submits one tagged
    // frame, then we drain one result from every sink before the next
    // round — after round 0 all members are registered, so each round is
    // one coalesced dispatch, not M single-frame calls.
    for seq in 0..ROUNDS {
        for (i, h) in feeds.iter().enumerate() {
            h.push(Buffer::new(vec![i as u8, seq])).unwrap();
        }
        for (i, rx) in sinks.iter().enumerate() {
            let got = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(
                &got.data[..],
                &[i as u8, seq],
                "demux routed pipeline {i}'s frame elsewhere in round {seq}"
            );
        }
    }
    drop(feeds);
    for r in running {
        assert_eq!(r.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
    }
    let sizes = sizes.lock().unwrap();
    let max = sizes.iter().copied().max().unwrap_or(0);
    assert!(
        max >= 2,
        "M=8 round-synchronized submits never coalesced: batch sizes {sizes:?}"
    );
    let frames: usize = sizes.iter().sum();
    assert_eq!(frames, M * ROUNDS as usize, "conservation through the collector");
}

#[test]
fn per_pipeline_frame_order_is_preserved() {
    const M: usize = 4;
    const N: u8 = 50;
    let (collector, _sizes) = echo_collector(
        "t_order",
        BatchCfg { max_batch: M, timeout: Duration::from_millis(20) },
        Duration::ZERO,
    );
    let mut running = Vec::new();
    let mut feeds = Vec::new();
    let mut sinks = Vec::new();
    for _ in 0..M {
        let (r, h, rx) = member_pipeline(&collector);
        running.push(r);
        feeds.push(h);
        sinks.push(rx);
    }
    // Unsynchronized firehose: batches form however scheduling lands.
    for seq in 0..N {
        for (i, h) in feeds.iter().enumerate() {
            h.push(Buffer::new(vec![i as u8, seq])).unwrap();
        }
    }
    for (i, rx) in sinks.iter().enumerate() {
        for seq in 0..N {
            let got = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(got.data[0], i as u8, "cross-pipeline demux leak");
            assert_eq!(got.data[1], seq, "pipeline {i} frames reordered");
        }
    }
    drop(feeds);
    for r in running {
        assert_eq!(r.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
    }
}

#[test]
fn m1_adaptive_target_adds_no_batch_latency() {
    // One member, max_batch=64, 10 s budget: the adaptive target
    // (min(B, members)) must dispatch every frame immediately — if the
    // filter waited for the timer this test would take minutes.
    let (collector, sizes) = echo_collector(
        "t_m1",
        BatchCfg { max_batch: 64, timeout: Duration::from_secs(10) },
        Duration::ZERO,
    );
    let (r, h, rx) = member_pipeline(&collector);
    let t0 = Instant::now();
    for seq in 0..20u8 {
        h.push(Buffer::new(vec![seq])).unwrap();
        let got = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got.data[0], seq);
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "M=1 frames waited on the batch budget ({:?})",
        t0.elapsed()
    );
    drop(h);
    assert_eq!(r.wait_eos(Duration::from_secs(10)), WaitOutcome::Eos);
    assert!(sizes.lock().unwrap().iter().all(|&s| s == 1));
}

#[test]
fn full_flush_and_timer_flush_both_counted() {
    const LABEL: &str = "t_flush_paths";
    let (collector, _sizes) = echo_collector(
        LABEL,
        BatchCfg { max_batch: 2, timeout: Duration::from_millis(30) },
        Duration::ZERO,
    );
    let g = edgepipe::metrics::global();
    let full0 = g.counter(&format!("batch.{LABEL}.flushes_full")).count();
    let timer0 = g.counter(&format!("batch.{LABEL}.flushes_timer")).count();
    let (r1, h1, rx1) = member_pipeline(&collector);
    let (r2, h2, rx2) = member_pipeline(&collector);
    // Warm-up round so both members are registered (target = 2).
    h1.push(Buffer::new(vec![1])).unwrap();
    h2.push(Buffer::new(vec![2])).unwrap();
    rx1.recv_timeout(Duration::from_secs(30)).unwrap();
    rx2.recv_timeout(Duration::from_secs(30)).unwrap();
    // A matched pair: the second submit completes the batch (full flush).
    h1.push(Buffer::new(vec![3])).unwrap();
    h2.push(Buffer::new(vec![4])).unwrap();
    rx1.recv_timeout(Duration::from_secs(30)).unwrap();
    rx2.recv_timeout(Duration::from_secs(30)).unwrap();
    assert!(
        g.counter(&format!("batch.{LABEL}.flushes_full")).count() > full0,
        "no full flush counted"
    );
    // A lone frame: only the 30 ms budget can release it (timer flush).
    h1.push(Buffer::new(vec![5])).unwrap();
    let got = rx1.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(&got.data[..], &[5]);
    assert!(
        g.counter(&format!("batch.{LABEL}.flushes_timer")).count() > timer0,
        "lone frame was not released by the latency budget"
    );
    drop((h1, h2));
    assert_eq!(r1.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
    assert_eq!(r2.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
}

/// Sink asserting frames arrive intact and strictly in order (drops
/// allowed, duplicates and corruption not).
struct OrderedCountSink {
    delivered: Arc<AtomicU64>,
    eos: Arc<AtomicU64>,
    last: Option<u64>,
}

impl Element for OrderedCountSink {
    fn n_src_pads(&self) -> usize {
        0
    }
    fn handle(&mut self, _: usize, item: Item, _: &mut Ctx) -> Result<()> {
        match item {
            Item::Buffer(b) => {
                let mut v = [0u8; 8];
                v.copy_from_slice(&b.data[..8]);
                let seq = u64::from_le_bytes(v);
                if let Some(prev) = self.last {
                    assert!(seq > prev, "duplicate or reordered frame after leak: {prev} -> {seq}");
                }
                self.last = Some(seq);
                self.delivered.fetch_add(1, Ordering::Relaxed);
            }
            Item::Eos => {
                self.eos.fetch_add(1, Ordering::Relaxed);
            }
            Item::Caps(_) => {}
        }
        Ok(())
    }
}

/// Unthrottled pooled source that emits sticky caps before flooding.
struct CapsyFloodSrc {
    n: u64,
    sent: u64,
    caps_sent: bool,
}

impl Element for CapsyFloodSrc {
    fn n_sink_pads(&self) -> usize {
        0
    }
    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
        unreachable!()
    }
    fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
        if !self.caps_sent {
            self.caps_sent = true;
            ctx.push_caps(Caps::any())?;
            return Ok(true);
        }
        if self.sent >= self.n {
            return Ok(false);
        }
        ctx.push_buffer(Buffer::new(self.sent.to_le_bytes().to_vec()))?;
        self.sent += 1;
        Ok(true)
    }
}

#[test]
fn leaky_inbox_conservation_with_caps() {
    let (collector, _sizes) = echo_collector(
        "t_leaky_caps",
        BatchCfg { max_batch: 8, timeout: Duration::from_millis(5) },
        Duration::from_millis(2),
    );
    let delivered = Arc::new(AtomicU64::new(0));
    let eos = Arc::new(AtomicU64::new(0));
    let mut p = Pipeline::new();
    let s = p.add("src", Box::new(CapsyFloodSrc { n: 500, sent: 0, caps_sent: false })).unwrap();
    let q = p.add("q", Box::new(Queue::new(2, Leaky::Downstream))).unwrap();
    let f = p.add("f", Box::new(TensorFilter::batched(collector))).unwrap();
    let k = p
        .add(
            "k",
            Box::new(OrderedCountSink {
                delivered: delivered.clone(),
                eos: eos.clone(),
                last: None,
            }),
        )
        .unwrap();
    p.link(s, q).unwrap();
    p.link(q, f).unwrap();
    p.link(f, k).unwrap();
    let running = p.start_mode(ExecMode::Pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(60)), WaitOutcome::Eos);
    let d = delivered.load(Ordering::Relaxed);
    assert!(d >= 1, "nothing delivered");
    assert!(d <= 500, "duplication under leak");
    assert!(d < 500, "2-deep leaky queue against a 2 ms/dispatch backend never leaked");
    assert_eq!(eos.load(Ordering::Relaxed), 1, "EOS lost under leak");
}

#[test]
fn batched_description_runs_end_to_end() {
    // The parser path: batch=/batch-timeout-ms= on a passthrough filter.
    use edgepipe::element::registry::{PipelineEnv, Registry};
    let p = edgepipe::pipeline::parser::parse(
        "videotestsrc width=4 height=4 is-live=false num-buffers=20 ! \
         tensor_converter ! tensor_filter framework=passthrough batch=4 batch-timeout-ms=5 ! \
         fakesink",
        &Registry::with_builtins(),
        &PipelineEnv::default(),
    )
    .unwrap();
    let running = p.start_mode(ExecMode::Pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
}
