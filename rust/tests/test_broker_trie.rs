//! Trie/linear equivalence and sharded-router behavior.
//!
//! The subscription trie ([`edgepipe::mqtt::trie::SubTrie`]) and the
//! retained-topic trie are the broker's production matching paths; the
//! linear [`topic::matches`] scan is the REFERENCE implementation of
//! MQTT 3.1.1 §4.7. These property tests drive both over randomized
//! topic/filter pairs — including `$`-first topics (§4.7.2), `#`/`+`
//! edge cases, and empty levels — so the trie can never silently drift
//! from the spec semantics the rest of the repo pins with unit tests.

use std::sync::mpsc::sync_channel;

use edgepipe::buffer::Bytes;
use edgepipe::metrics;
use edgepipe::mqtt::broker::OutMsg;
use edgepipe::mqtt::topic;
use edgepipe::mqtt::trie::{RetainedTrie, SubTrie};
use edgepipe::mqtt::Router;
use edgepipe::testkit;

// ---------------------------------------------------------------------------
// Randomized topic/filter generation
// ---------------------------------------------------------------------------

/// Deliberately tiny level alphabet so random topics and filters collide
/// often — equivalence tests on disjoint namespaces would never exercise
/// the interesting overlaps. Includes `$`-levels (§4.7.2) and the empty
/// level (`/a/b` leading-slash semantics).
const LEVELS: &[&str] = &["a", "b", "c", "dev0", "$SYS", "$edge", ""];

fn gen_topic(g: &mut testkit::Gen) -> String {
    let depth = g.usize(1, 4);
    (0..depth).map(|_| *g.choose(LEVELS)).collect::<Vec<_>>().join("/")
}

/// A random VALID filter: `+` only as a whole level, `#` only last.
fn gen_filter(g: &mut testkit::Gen) -> String {
    let depth = g.usize(1, 4);
    let mut levels: Vec<&str> = (0..depth)
        .map(|_| if g.bool(0.25) { "+" } else { *g.choose(LEVELS) })
        .collect();
    if g.bool(0.3) {
        if g.bool(0.5) {
            levels.push("#");
        } else {
            *levels.last_mut().unwrap() = "#";
        }
    }
    let mut f = levels.join("/");
    if f.is_empty() {
        // Sole invalid shape a draw can produce: one empty level.
        f.push('+');
    }
    topic::validate_filter(&f).expect("generator must emit valid filters");
    f
}

// ---------------------------------------------------------------------------
// Equivalence properties
// ---------------------------------------------------------------------------

#[test]
fn prop_subtrie_agrees_with_linear_matches() {
    testkit::check(300, |g| {
        let n_filters = g.usize(1, 24);
        let filters: Vec<String> = (0..n_filters).map(|_| gen_filter(g)).collect();
        let mut trie = SubTrie::new();
        for (i, f) in filters.iter().enumerate() {
            trie.insert(f, i);
        }
        assert_eq!(trie.len(), filters.len());
        for _ in 0..8 {
            let t = gen_topic(g);
            let mut via_trie: Vec<usize> = trie.matches(&t).into_iter().copied().collect();
            via_trie.sort_unstable();
            let via_linear: Vec<usize> = filters
                .iter()
                .enumerate()
                .filter(|(_, f)| topic::matches(f, &t))
                .map(|(i, _)| i)
                .collect();
            assert_eq!(
                via_trie, via_linear,
                "trie/linear disagree on topic `{t}` over filters {filters:?}"
            );
        }
    });
}

#[test]
fn prop_subtrie_agrees_after_random_removals() {
    testkit::check(150, |g| {
        let n_filters = g.usize(2, 16);
        let filters: Vec<String> = (0..n_filters).map(|_| gen_filter(g)).collect();
        let mut trie = SubTrie::new();
        for (i, f) in filters.iter().enumerate() {
            trie.insert(f, i);
        }
        // Remove a random subset (by value) through their filters.
        let mut alive = vec![true; filters.len()];
        for (i, f) in filters.iter().enumerate() {
            if g.bool(0.4) {
                let removed = trie.remove_where(f, |v| *v == i);
                assert_eq!(removed, 1, "value {i} under `{f}` must be removable");
                alive[i] = false;
            }
        }
        assert_eq!(trie.len(), alive.iter().filter(|a| **a).count());
        for _ in 0..6 {
            let t = gen_topic(g);
            let mut via_trie: Vec<usize> = trie.matches(&t).into_iter().copied().collect();
            via_trie.sort_unstable();
            let via_linear: Vec<usize> = filters
                .iter()
                .enumerate()
                .filter(|(i, f)| alive[*i] && topic::matches(f, &t))
                .map(|(i, _)| i)
                .collect();
            assert_eq!(via_trie, via_linear, "post-removal disagree on `{t}`");
        }
    });
}

#[test]
fn prop_retained_trie_agrees_with_linear_scan() {
    testkit::check(200, |g| {
        let n_topics = g.usize(1, 16);
        let mut stored: Vec<String> = (0..n_topics).map(|_| gen_topic(g)).collect();
        stored.sort();
        stored.dedup();
        let mut trie = RetainedTrie::new();
        for t in &stored {
            trie.insert(t, Bytes::from(t.as_bytes().to_vec()));
        }
        assert_eq!(trie.len(), stored.len());
        for _ in 0..8 {
            let f = gen_filter(g);
            let mut out = Vec::new();
            trie.collect_matching(&f, &mut out);
            let mut via_trie: Vec<String> = out.iter().map(|r| r.topic.to_string()).collect();
            via_trie.sort();
            let via_linear: Vec<String> =
                stored.iter().filter(|t| topic::matches(&f, t)).cloned().collect();
            assert_eq!(
                via_trie, via_linear,
                "retained trie/linear disagree on filter `{f}` over {stored:?}"
            );
            // Payload must be the stored bytes, shared — not re-encoded.
            for r in &out {
                assert_eq!(r.payload.as_slice(), r.topic.as_bytes());
            }
        }
    });
}

#[test]
fn subtrie_pinned_edge_cases() {
    // The §4.7 corner cases the property alphabet might hit rarely,
    // pinned explicitly (mirrors `topic::matches` unit tests).
    let cases: &[(&str, &str, bool)] = &[
        ("sport/tennis/#", "sport/tennis", true), // '#' matches its parent
        ("sport/tennis/#", "sport", false),
        ("#", "$SYS/broker", false), // §4.7.2
        ("+/broker", "$SYS/broker", false),
        ("$SYS/#", "$SYS/broker", true),
        ("$SYS/#", "$SYS", true),
        ("a/#", "a/$weird", true), // '$' deeper is ordinary
        ("a/+", "a/$weird", true),
        ("+", "", true),  // empty single level
        ("/+", "/a", true),
        ("+/a", "/a", true), // '+' fills the empty first level
        ("a//b", "a//b", true),
        ("a/+/b", "a//b", true),
    ];
    for (filter, topic_name, expect) in cases {
        let mut trie = SubTrie::new();
        trie.insert(filter, 0u8);
        assert_eq!(
            !trie.matches(topic_name).is_empty(),
            *expect,
            "trie: filter `{filter}` vs topic `{topic_name}`"
        );
        assert_eq!(
            topic::matches(filter, topic_name),
            *expect,
            "reference: filter `{filter}` vs topic `{topic_name}`"
        );
    }
}

// ---------------------------------------------------------------------------
// Sharded Router behavior (driven directly, no sockets)
// ---------------------------------------------------------------------------

fn drain(rx: &std::sync::mpsc::Receiver<OutMsg>) -> usize {
    let mut n = 0;
    while let Ok(msg) = rx.try_recv() {
        if matches!(msg, OutMsg::Pub { .. }) {
            n += 1;
        }
    }
    n
}

#[test]
fn router_wildcard_filter_spans_all_shards() {
    let router = Router::new(4);
    assert_eq!(router.shard_count(), 4);
    let (tx, rx) = sync_channel(64);
    router.session_open(1, "watcher".into(), tx, None);
    router.subscribe(1, "#", 0);
    // Distinct first levels hash to (almost certainly) different shards;
    // a '#' subscriber must see every one of them regardless.
    let topics = ["a/1", "b/2", "c/3", "dev0/4", "e/5", "f/6", "g/7", "h/8"];
    for t in &topics {
        let (delivered, dropped) = router.publish(t, &Bytes::from(b"x".to_vec()), false);
        assert_eq!((delivered, dropped), (1, 0), "publish on `{t}`");
    }
    assert_eq!(drain(&rx), topics.len());
}

#[test]
fn router_dedups_overlapping_filters_per_session() {
    let router = Router::new(4);
    let (tx, rx) = sync_channel(64);
    router.session_open(7, "c".into(), tx, None);
    router.subscribe(7, "a/#", 0);
    router.subscribe(7, "a/b", 0);
    router.subscribe(7, "a/+", 0);
    let (delivered, _) = router.publish("a/b", &Bytes::from(b"x".to_vec()), false);
    assert_eq!(delivered, 1, "one delivery per session under overlapping filters");
    assert_eq!(drain(&rx), 1);
    // Re-subscribing the same filter must not double-deliver either.
    router.subscribe(7, "a/b", 0);
    let (delivered, _) = router.publish("a/b", &Bytes::from(b"y".to_vec()), false);
    assert_eq!(delivered, 1);
}

#[test]
fn router_retained_lookup_crosses_shards_for_wildcard_filters() {
    let router = Router::new(4);
    let (tx_pub, _rx_pub) = sync_channel(4);
    router.session_open(1, "adv".into(), tx_pub, None);
    // Retained topics with different first levels live in different shards.
    for t in ["svc/a", "other/b", "third/c", "$SYS/hidden"] {
        router.publish(t, &Bytes::from(t.as_bytes().to_vec()), true);
    }
    let (tx, _rx) = sync_channel(16);
    router.session_open(2, "late".into(), tx, None);
    // Wildcard-leading filter: retained from EVERY shard, minus '$'.
    let mut got: Vec<String> =
        router.subscribe(2, "#", 0).iter().map(|r| r.topic.to_string()).collect();
    got.sort();
    assert_eq!(got, vec!["other/b", "svc/a", "third/c"]);
    // Literal-first filter: resolved from one shard only, still correct.
    let got = router.subscribe(2, "svc/+", 0);
    assert_eq!(got.len(), 1);
    assert_eq!(&*got[0].topic, "svc/a");
    assert_eq!(got[0].payload.as_slice(), b"svc/a");
    // Empty-payload publish clears across the shard set.
    router.publish("svc/a", &Bytes::from(Vec::new()), true);
    assert!(router.subscribe(2, "svc/+", 0).is_empty());
    assert_eq!(router.retained_topics(), vec!["$SYS/hidden", "other/b", "third/c"]);
}

#[test]
fn router_session_close_removes_replicated_subscriptions() {
    let router = Router::new(4);
    let (tx, rx) = sync_channel(64);
    router.session_open(3, "c".into(), tx, None);
    router.subscribe(3, "#", 0); // replicated into all 4 shards
    router.subscribe(3, "lit/x", 0);
    assert_eq!(router.publish("lit/x", &Bytes::from(b"1".to_vec()), false).0, 1);
    let will = router.session_close(3);
    assert!(will.is_none());
    assert_eq!(router.session_count(), 0);
    for t in ["lit/x", "a/b", "c/d", "e/f"] {
        assert_eq!(
            router.publish(t, &Bytes::from(b"2".to_vec()), false).0,
            0,
            "no delivery to a closed session (topic `{t}`)"
        );
    }
    drop(rx);
}

#[test]
fn router_unsubscribe_is_scoped_to_filter_and_session() {
    let router = Router::new(2);
    let (tx1, rx1) = sync_channel(16);
    let (tx2, rx2) = sync_channel(16);
    router.session_open(1, "one".into(), tx1, None);
    router.session_open(2, "two".into(), tx2, None);
    router.subscribe(1, "t/+", 0);
    router.subscribe(2, "t/+", 0);
    router.unsubscribe(1, "t/+");
    let (delivered, _) = router.publish("t/x", &Bytes::from(b"p".to_vec()), false);
    assert_eq!(delivered, 1);
    assert_eq!(drain(&rx1), 0);
    assert_eq!(drain(&rx2), 1);
}

#[test]
fn router_per_shard_metrics_are_registered_and_counted() {
    let before: u64 = (0..3)
        .map(|i| metrics::global().counter(&format!("broker.shard{i}.publishes")).count())
        .sum();
    let router = Router::new(3);
    let (tx, _rx) = sync_channel(64);
    router.session_open(1, "m".into(), tx, None);
    router.subscribe(1, "#", 0);
    for t in ["a/one", "b/two", "c/three", "dev0/four"] {
        router.publish(t, &Bytes::from(b"x".to_vec()), false);
    }
    let names = metrics::global().counter_names();
    for i in 0..3 {
        for kind in ["publishes", "matches", "lock_waits"] {
            let name = format!("broker.shard{i}.{kind}");
            assert!(names.contains(&name), "missing counter {name}");
        }
    }
    let after: u64 = (0..3)
        .map(|i| metrics::global().counter(&format!("broker.shard{i}.publishes")).count())
        .sum();
    assert_eq!(after - before, 4, "each publish ticks exactly one shard");
    let stats = router.stats();
    assert_eq!(stats.published, 4);
    assert_eq!(stats.delivered, 4);
}

#[test]
fn router_shard_count_resolves_env_and_clamps() {
    // Explicit count wins; 0 resolves from env/default but never below 1.
    assert_eq!(Router::new(5).shard_count(), 5);
    assert!(Router::new(0).shard_count() >= 1);
}
