//! Stateful per-link codec stack under fire: delta-chain resync across
//! a fault-injected loss window, and randomized delta/sparse round-trips
//! checked against the plain-zlib oracle.

use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

use edgepipe::buffer::{Buffer, Bytes};
use edgepipe::caps::Caps;
use edgepipe::metrics;
use edgepipe::serial::wire::{self, LinkCodec, LinkDecoder};
use edgepipe::serial::Codec;
use edgepipe::tensor::{f32_to_bytes, DType, TensorInfo, TensorsInfo};
use edgepipe::testkit::fault::{Fault, FaultProxy};
use edgepipe::util::rng::XorShift64;

/// Correlated frame `i`: a constant base with the frame index stamped in
/// the first 8 bytes and a handful of drifting bytes — the shape delta
/// coding exists for. The index stamp doubles as the corruption check.
fn correlated(i: u64, len: usize) -> Vec<u8> {
    let mut v = vec![3u8; len];
    v[..8].copy_from_slice(&i.to_le_bytes());
    let step = (i as usize * 131) % (len - 8);
    v[8 + step] = (i % 251) as u8;
    v
}

// ---------------------------------------------------------------------------
// Satellite: decoder resync under loss (FaultProxy black-hole window)
// ---------------------------------------------------------------------------

#[test]
fn delta_link_resyncs_after_blackhole_window() {
    const LEN: usize = 4096;
    const N: u64 = 24;
    const INTERVAL: u64 = 8; // keyframes at 0, 8, 16

    // Receiver: a raw TCP reader draining wire frames through a
    // LinkDecoder, reporting each delivered frame's stamped index (or a
    // corruption marker) back to the test thread.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let upstream = listener.local_addr().unwrap().to_string();
    let (tx, rx) = mpsc::channel::<Result<u64, String>>();
    let reader = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(3))).unwrap();
        let mut dec = LinkDecoder::new("stack.loss");
        loop {
            let frame = match wire::read_frame(&mut conn) {
                Ok(f) => f,
                Err(_) => break, // EOF or timeout: sender is done
            };
            match dec.decode(&frame) {
                Ok(Some((buf, _caps))) => {
                    let i = u64::from_le_bytes(buf.data[..8].try_into().unwrap());
                    let verdict = if buf.data[..] == correlated(i, LEN)[..] {
                        Ok(i)
                    } else {
                        Err(format!("frame {i} corrupt"))
                    };
                    tx.send(verdict).unwrap();
                }
                Ok(None) => {} // mid-chain delta dropped after loss — expected
                Err(e) => {
                    tx.send(Err(format!("decode error: {e}"))).unwrap();
                    break;
                }
            }
        }
    });

    // Sender: delta-coded link through the fault proxy. Frames are paced
    // and much smaller than the proxy's 16 KiB pump buffer, so one
    // swallowed chunk is one whole lost frame (clean frame loss, not
    // byte-level corruption — TCP framing stays intact for what passes).
    let proxy = FaultProxy::start(&upstream).unwrap();
    let mut conn = TcpStream::connect(proxy.addr()).unwrap();
    conn.set_nodelay(true).unwrap();
    let mut enc = LinkCodec::new(Codec::Delta, "stack.loss.enc").with_keyframe_interval(INTERVAL);
    for i in 0..N {
        if i == 6 {
            // Let in-flight bytes drain, then swallow frames 6..=9
            // (covers the keyframe at 8, so recovery needs frame 16).
            std::thread::sleep(Duration::from_millis(80));
            proxy.set(Fault::BlackHole);
        }
        if i == 10 {
            std::thread::sleep(Duration::from_millis(80));
            proxy.set(Fault::Pass);
        }
        let buf = Buffer::new(correlated(i, LEN)).with_pts(i);
        let f = enc.encode(&buf, None).unwrap();
        wire::write_frame_vectored(&mut conn, &f).unwrap();
        std::thread::sleep(Duration::from_millis(15));
    }
    std::thread::sleep(Duration::from_millis(200));
    drop(conn);
    drop(proxy);
    reader.join().unwrap();

    let mut delivered = Vec::new();
    while let Ok(v) = rx.try_recv() {
        delivered.push(v.expect("no corrupt frame may ever be delivered"));
    }
    // Frames 0..=5 arrive synced; 6..=9 are swallowed (including the
    // keyframe at 8); 10..=15 are mid-chain deltas with no chain state
    // and must be DROPPED, not garbled; 16 rekeys and 16..=23 flow.
    let expected: Vec<u64> = (0..=5).chain(16..N).collect();
    assert_eq!(delivered, expected, "delivery must pause cleanly until the next keyframe");
    let resyncs = metrics::global().counter("codec.delta.stack.loss.resyncs").count();
    assert!(resyncs >= 1, "loss window must count at least one resync (got {resyncs})");
}

// ---------------------------------------------------------------------------
// Satellite: randomized round-trips vs the plain-zlib oracle
// ---------------------------------------------------------------------------

#[test]
fn randomized_delta_stream_matches_zlib_oracle() {
    let mut rng = XorShift64::new(0xC0DEC);
    for link_no in 0..3u64 {
        let len = 1000 + rng.below(4000) as usize;
        let mut payload = vec![0u8; len];
        rng.fill_bytes(&mut payload);
        let mut enc =
            LinkCodec::new(Codec::Delta, "").with_keyframe_interval(1 + rng.below(9));
        let mut dec = LinkDecoder::new("");
        for i in 0..30u64 {
            // Mutate a few random bytes (correlated stream); every 10th
            // frame change the length, which must force a keyframe.
            for _ in 0..rng.below(8) {
                let at = rng.below(payload.len() as u64) as usize;
                payload[at] = rng.next_u32() as u8;
            }
            if i % 10 == 9 {
                payload.push(rng.next_u32() as u8);
            }
            let buf = Buffer::new(payload.clone()).with_pts(link_no * 100 + i);

            // Oracle: the same buffer through the stateless zlib path.
            let oracle_frame = wire::encode_vectored(&buf, None, Codec::Zlib).unwrap();
            let (oracle, _) = wire::decode_shared(&Bytes::from(oracle_frame.to_vec())).unwrap();

            let f = enc.encode(&buf, None).unwrap();
            let (out, _) =
                dec.decode(&Bytes::from(f.to_vec())).unwrap().expect("lossless link never drops");
            assert_eq!(&out.data[..], &oracle.data[..], "link {link_no} frame {i}");
            assert_eq!(&out.data[..], &payload[..]);
            assert_eq!(out.pts, Some(link_no * 100 + i));
        }
    }
}

#[test]
fn randomized_sparse_tensors_roundtrip_exactly() {
    let mut rng = XorShift64::new(0x5EED5);
    for round in 0..8u64 {
        let n = 256 + rng.below(4096) as usize;
        let info = TensorsInfo::one(TensorInfo::new(DType::F32, &[n as u32]).unwrap());
        let caps = Caps::tensors(&info);
        let mut vals = vec![0.0f32; n];
        // Densities from "one element" up to ~20%.
        let nnz = 1 + rng.below((n / 5) as u64) as usize;
        for _ in 0..nnz {
            let at = rng.below(n as u64) as usize;
            vals[at] = rng.normal();
        }
        let payload = f32_to_bytes(&vals);
        let buf = Buffer::new(payload.clone()).with_pts(round);

        let mut enc = LinkCodec::new(Codec::Sparse, "");
        let f = enc.encode(&buf, Some(&caps)).unwrap();
        let raw = Bytes::from(f.to_vec());

        // Both the stateless and the stateful decoder must reproduce the
        // dense payload bit-for-bit (same check as the zlib oracle: the
        // source buffer itself is the reference).
        let (out, c) = wire::decode_shared(&raw).unwrap();
        assert_eq!(&out.data[..], &payload[..], "round {round}");
        assert_eq!(c.unwrap(), caps);
        let mut dec = LinkDecoder::new("");
        let (out2, _) = dec.decode(&raw).unwrap().expect("sparse frames are self-contained");
        assert_eq!(&out2.data[..], &payload[..]);
        assert_eq!(out2.pts, Some(round));
    }
}
