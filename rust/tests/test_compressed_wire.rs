//! Compressed-hop invariants and hostile-input hardening for the
//! streaming zlib wire path: single-allocation encode, guarded streaming
//! decode (truncation, bombs, unknown codec flags), and `Codec::Auto`
//! end-to-end behaviour.

use std::time::Duration;

use edgepipe::buffer::{bytes_copied, Buffer, Bytes};
use edgepipe::caps::Caps;
use edgepipe::mqtt::{Broker, ClientOptions, MqttClient};
use edgepipe::serial::compress::{self, AutoCodec, Codec, MAX_DECOMPRESSED};
use edgepipe::serial::wire;
use edgepipe::util::rng::XorShift64;
use edgepipe::util::Error;

fn noise(n: usize, seed: u64) -> Vec<u8> {
    let mut v = vec![0u8; n];
    XorShift64::new(seed).fill_bytes(&mut v);
    v
}

// ---------------------------------------------------------------------------
// One-allocation invariants
// ---------------------------------------------------------------------------

#[test]
fn zlib_encode_is_one_allocation_and_zero_counted_copies() {
    let buf = Buffer::new(vec![7u8; 200_000]).with_pts(3);
    let caps = Caps::video(64, 64, 30);
    let before = bytes_copied();
    let f = wire::encode_vectored(&buf, Some(&caps), Codec::Zlib).unwrap();
    assert_eq!(bytes_copied(), before, "in-place deflate must not count payload copies");
    assert!(f.header.same_backing(&f.payload), "header and compressed payload must share");
    assert!(f.payload.len() < buf.len() / 10);
}

#[test]
fn zlib_decode_streams_into_a_single_fresh_allocation() {
    let buf = Buffer::new(vec![5u8; 100_000]);
    let f = wire::encode_vectored(&buf, None, Codec::Zlib).unwrap();
    let frame = Bytes::from(f.to_vec());
    let before = bytes_copied();
    let (out, _) = wire::decode_shared(&frame).unwrap();
    assert_eq!(bytes_copied(), before, "streamed inflate must not count payload copies");
    assert_eq!(&out.data[..], &buf.data[..]);
    assert!(!out.data.same_backing(&frame), "inflated payload is its own allocation");
}

#[test]
fn compressed_query_hop_roundtrips_through_stream_framing() {
    let buf = Buffer::new(vec![9u8; 50_000]).with_pts(11);
    let f = wire::encode_vectored(&buf, None, Codec::Zlib).unwrap();
    let mut sock = Vec::new();
    wire::write_frame_vectored(&mut sock, &f).unwrap();
    let mut cur = std::io::Cursor::new(&sock[..]);
    let received = wire::read_frame(&mut cur).unwrap();
    let (out, _) = wire::decode_shared(&received).unwrap();
    assert_eq!(&out.data[..], &buf.data[..]);
    assert_eq!(out.pts, Some(11));
}

// ---------------------------------------------------------------------------
// Hostile input
// ---------------------------------------------------------------------------

#[test]
fn truncated_deflate_stream_is_serial_error() {
    let data = vec![1u8; 40_000];
    let c = compress::compress(Codec::Zlib, &data).unwrap();
    for cut in [0, 1, c.len() / 3, c.len() - 1] {
        match compress::inflate_guarded(&c[..cut], MAX_DECOMPRESSED) {
            Err(Error::Serial(_)) => {}
            other => panic!("cut {cut}: expected Error::Serial, got {other:?}"),
        }
    }
}

#[test]
fn truncated_compressed_wire_frame_is_serial_error() {
    let buf = Buffer::new(vec![2u8; 30_000]);
    let f = wire::encode_vectored(&buf, None, Codec::Zlib).unwrap();
    let hlen = f.header.len();
    let mut raw = f.to_vec();
    // Chop the compressed tail but keep the declared payload length
    // consistent, so the framing check passes and the inflater must
    // detect the truncation itself.
    raw.truncate(raw.len() - 5);
    let plen = (f.payload.len() - 5) as u32;
    raw[hlen - 4..hlen].copy_from_slice(&plen.to_le_bytes());
    match wire::decode_shared(&Bytes::from(raw)) {
        Err(Error::Serial(_)) => {}
        other => panic!("expected Error::Serial, got {other:?}"),
    }
}

#[test]
fn zlib_bomb_is_rejected_mid_stream_without_inflating_it() {
    // 8 MiB of zeros -> a few KiB of deflate. Inflating under a 256 KiB
    // budget must fail as soon as the limit is crossed.
    let zeros = vec![0u8; 8 * 1024 * 1024];
    let c = compress::compress(Codec::Zlib, &zeros).unwrap();
    assert!(c.len() < 64 * 1024, "bomb input should be tiny ({} bytes)", c.len());
    match compress::inflate_guarded(&c, 256 * 1024) {
        Err(Error::Serial(msg)) => assert!(msg.contains("limit"), "{msg}"),
        other => panic!("expected Error::Serial, got {other:?}"),
    }
}

#[test]
fn garbage_compressed_payload_is_serial_error() {
    // A structurally valid EdgeFrame whose "compressed" payload is noise.
    let bogus = Buffer::new(noise(512, 3));
    let f = wire::encode_vectored(&bogus, None, Codec::None).unwrap();
    let mut raw = f.to_vec();
    raw[6] = 1; // flip the codec flag to zlib; payload is not a zlib stream
    match wire::decode_shared(&Bytes::from(raw)) {
        Err(Error::Serial(_)) => {}
        other => panic!("expected Error::Serial, got {other:?}"),
    }
}

#[test]
fn unknown_codec_flag_byte_is_serial_error() {
    // 2 is the Auto policy discriminant (never valid on the wire);
    // 5..=255 are unassigned. 3 (delta) and 4 (sparse) are real codecs
    // now and get their own stateless-rejection test below.
    let buf = Buffer::new(vec![1, 2, 3, 4]);
    let f = wire::encode_vectored(&buf, None, Codec::None).unwrap();
    for flag in [2u8, 5, 0x7F, 0xFF] {
        let mut raw = f.to_vec();
        raw[6] = flag;
        match wire::decode_shared(&Bytes::from(raw.clone())) {
            Err(Error::Serial(_)) => {}
            other => panic!("flag {flag}: expected Error::Serial, got {other:?}"),
        }
        match wire::decode(&raw) {
            Err(Error::Serial(_)) => {}
            other => panic!("flag {flag}: expected Error::Serial, got {other:?}"),
        }
    }
}

#[test]
fn stateful_codec_bytes_are_rejected_by_stateless_decode() {
    let buf = Buffer::new(vec![1, 2, 3, 4]);
    let f = wire::encode_vectored(&buf, None, Codec::None).unwrap();
    // Codec byte 3 without the keyframe flag claims a mid-chain delta:
    // undecodable without the link's previous frame.
    let mut raw = f.to_vec();
    raw[6] = 3;
    match wire::decode_shared(&Bytes::from(raw)) {
        Err(Error::Serial(msg)) => assert!(msg.contains("LinkDecoder"), "{msg}"),
        other => panic!("mid-chain delta: expected Error::Serial, got {other:?}"),
    }
    // Codec byte 4 claims a sparse payload; [1,2,3,4] has no COO magic.
    let mut raw = f.to_vec();
    raw[6] = 4;
    match wire::decode_shared(&Bytes::from(raw)) {
        Err(Error::Serial(_)) => {}
        other => panic!("bogus sparse: expected Error::Serial, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Codec::Auto
// ---------------------------------------------------------------------------

#[test]
fn auto_keeps_incompressible_payloads_shared() {
    let buf = Buffer::new(noise(64 * 1024, 77));
    let f = wire::encode_vectored(&buf, None, Codec::Auto).unwrap();
    // The probe deflate didn't win, so the frame must be pass-through AND
    // share the buffer's allocation (no wasted compressed copy).
    assert!(f.payload.same_backing(&buf.data));
    let (out, _) = wire::decode_shared(&Bytes::from(f.to_vec())).unwrap();
    assert_eq!(&out.data[..], &buf.data[..]);
}

#[test]
fn auto_link_state_learns_then_reprobes() {
    let mut auto = AutoCodec::new("test.integration");
    let caps = Caps::video(32, 32, 30);
    let noisy = Buffer::new(noise(32 * 32 * 3, 5));
    for _ in 0..10 {
        wire::encode_vectored_auto(&noisy, Some(&caps), &mut auto).unwrap();
    }
    assert!(!auto.is_compressing(), "noise must switch the link to pass-through");
    let tensorish = Buffer::new(vec![4u8; 32 * 32 * 3]);
    for _ in 0..(auto.probe_interval + 2) {
        wire::encode_vectored_auto(&tensorish, Some(&caps), &mut auto).unwrap();
    }
    assert!(auto.is_compressing(), "probe must re-enable zlib on compressible frames");
    let f = wire::encode_vectored_auto(&tensorish, Some(&caps), &mut auto).unwrap();
    assert!(f.payload.len() < tensorish.len(), "re-enabled link must compress again");
}

// ---------------------------------------------------------------------------
// End-to-end over a real broker
// ---------------------------------------------------------------------------

#[test]
fn compressed_fanout_shares_one_compressed_body() {
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let addr = broker.addr().to_string();
    let n_subs = 3;
    let mut rxs = Vec::new();
    let mut subs = Vec::new();
    for i in 0..n_subs {
        let c = MqttClient::connect(
            &addr,
            ClientOptions { client_id: format!("gz-sub-{i}"), ..Default::default() },
        )
        .unwrap();
        rxs.push(c.subscribe("gz/fan").unwrap());
        subs.push(c);
    }
    let publ = MqttClient::connect(
        &addr,
        ClientOptions { client_id: "gz-pub".into(), ..Default::default() },
    )
    .unwrap();
    std::thread::sleep(Duration::from_millis(200));

    let buf = Buffer::new(vec![6u8; 100_000]).with_pts(1);
    let caps = Caps::video(64, 64, 30);
    let frames = 5;
    for _ in 0..frames {
        let f = wire::encode_vectored(&buf, Some(&caps), Codec::Zlib).unwrap();
        assert!(f.header.same_backing(&f.payload));
        publ.publish_frame("gz/fan", &f, false).unwrap();
    }
    for rx in &rxs {
        for _ in 0..frames {
            let msg = rx.recv_timeout(Duration::from_secs(3)).unwrap();
            // The wire carried the compressed frame (much smaller than raw).
            assert!(msg.payload.len() < buf.len() / 10);
            let (out, c) = wire::decode_shared(&msg.payload).unwrap();
            assert_eq!(&out.data[..], &buf.data[..]);
            assert_eq!(c.unwrap(), caps);
        }
    }
    publ.disconnect();
    for c in &subs {
        c.disconnect();
    }
}
