//! Fault-injected resilience tests for query offload (ISSUE 6).
//!
//! Every scenario drives a real client pipeline against a real server
//! (or a fault-injecting proxy in front of one) and asserts the policy
//! layer's behavior: breaker transitions, backoff pacing, seq-stable
//! retransmits, leaky deadline drops, hedged tail-cutting, and recovery
//! after a peer restarts under the same server id.

use std::net::TcpListener;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use edgepipe::buffer::Buffer;
use edgepipe::caps::Caps;
use edgepipe::coordinator::discovery::{self, ServiceAd};
use edgepipe::coordinator::health::{self, BreakerConfig, BreakerState, HealthMap};
use edgepipe::elements::{
    AppSink, AppSrc, AppSrcHandle, QueryClient, QueryServerSink, QueryServerSrc, ResilienceConfig,
    TensorFilter,
};
use edgepipe::metrics;
use edgepipe::mqtt::{Broker, MqttClient};
use edgepipe::pipeline::{Pipeline, Running, WaitOutcome};
use edgepipe::serial::{wire, Codec};
use edgepipe::tensor::{DType, TensorInfo, TensorsInfo};
use edgepipe::testkit::fault::{Fault, FaultProxy};

fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

/// Server pipeline (serversrc -> x2 filter -> serversink) on `port`.
fn start_server(pair: &str, op: &str, port: u16, broker: Option<&str>, server_id: &str) -> Running {
    let mut src = QueryServerSrc::new(op)
        .with_pair_id(pair)
        .with_server_id(server_id)
        .with_bind(&format!("127.0.0.1:{port}"));
    if let Some(b) = broker {
        src = src.with_hybrid(b);
    }
    let mut p = Pipeline::new();
    let f = TensorFilter::custom(Box::new(|b: &Buffer| {
        Ok(b.data.iter().map(|&x| x.wrapping_mul(2)).collect())
    }));
    let s = p.add("ssrc", Box::new(src)).unwrap();
    let fi = p.add("f", Box::new(f)).unwrap();
    let k = p.add("ssink", Box::new(QueryServerSink::new(pair))).unwrap();
    p.link(s, fi).unwrap();
    p.link(fi, k).unwrap();
    p.start().unwrap()
}

/// Client pipeline around `client`, named `name` (unique per test so the
/// global `query.<name>.*` metrics don't cross-talk).
fn client_pipeline(name: &str, client: QueryClient) -> (Running, AppSrcHandle, Receiver<Buffer>) {
    let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[4]).unwrap());
    let mut p = Pipeline::new();
    let (src, h) = AppSrc::new(8, Some(Caps::tensors(&info)));
    let (sink, rx) = AppSink::new(8);
    let s = p.add("src", Box::new(src)).unwrap();
    let c = p.add(name, Box::new(client)).unwrap();
    let k = p.add("sink", Box::new(sink)).unwrap();
    p.link(s, c).unwrap();
    p.link(c, k).unwrap();
    (p.start().unwrap(), h, rx)
}

fn counter(name: &str, which: &str) -> u64 {
    metrics::global().counter(&format!("query.{name}.{which}")).count()
}

// ---------------------------------------------------------------------------
// Connect refused: backoff pacing + breaker opens
// ---------------------------------------------------------------------------

#[test]
fn refused_connect_backs_off_and_opens_breaker() {
    let addr = format!("127.0.0.1:{}", free_port()); // nothing listening
    let breaker = BreakerConfig {
        failure_threshold: 3,
        open_base: Duration::from_millis(200),
        ..Default::default()
    };
    let hm = Arc::new(HealthMap::new(breaker));
    let client = QueryClient::tcp("op-refused", &addr)
        .with_timeout(Duration::from_millis(500))
        .with_resilience(ResilienceConfig {
            retry: 4,
            backoff: Duration::from_millis(60),
            breaker,
            ..Default::default()
        })
        .with_health(hm.clone());
    let (mut running, h, _rx) = client_pipeline("qc_refuse", client);
    let t0 = Instant::now();
    h.push(Buffer::new(vec![1, 2, 3, 4])).unwrap();
    match running.wait(Duration::from_secs(10)) {
        WaitOutcome::Error { element, .. } => assert_eq!(element, "qc_refuse"),
        other => panic!("expected element error, got {other:?}"),
    }
    let elapsed = t0.elapsed();
    // 3 retries with exponential backoff (60/120/240ms, jitter >= 0.5x):
    // a hot reconnect loop would finish in single-digit milliseconds.
    assert!(elapsed >= Duration::from_millis(150), "no backoff pacing: {elapsed:?}");
    assert_eq!(hm.state(&addr), BreakerState::Open, "breaker should be open");
    assert!(counter("qc_refuse", "retries") >= 3, "retries counter");
    assert!(counter("qc_refuse", "breaker_open") >= 1, "breaker_open counter");
}

// ---------------------------------------------------------------------------
// Mid-stream RST: retry reconnects and the stream continues
// ---------------------------------------------------------------------------

#[test]
fn mid_stream_rst_recovers_via_retry() {
    let port = free_port();
    let server = start_server("rst", "op-rst", port, None, "rst");
    std::thread::sleep(Duration::from_millis(200));
    let proxy = FaultProxy::start(&format!("127.0.0.1:{port}")).unwrap();

    let client = QueryClient::tcp("op-rst", proxy.addr())
        .with_timeout(Duration::from_secs(2))
        .with_resilience(ResilienceConfig {
            backoff: Duration::from_millis(20),
            ..Default::default()
        });
    let (cr, h, rx) = client_pipeline("qc_rst", client);

    h.push(Buffer::new(vec![1, 2, 3, 4])).unwrap();
    assert_eq!(&rx.recv_timeout(Duration::from_secs(5)).unwrap().data[..], &[2, 4, 6, 8]);

    proxy.rst_all();
    std::thread::sleep(Duration::from_millis(100));

    h.push(Buffer::new(vec![2, 4, 6, 8])).unwrap();
    let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(&out.data[..], &[4, 8, 12, 16]);
    assert!(counter("qc_rst", "retries") >= 1, "RST must cost at least one retry");

    drop(h);
    let _ = cr.stop(Duration::from_secs(5));
    let _ = server.stop(Duration::from_secs(5));
}

// ---------------------------------------------------------------------------
// Read-timeout hang: deadline drops the frame, pipeline keeps flowing
// ---------------------------------------------------------------------------

#[test]
fn hung_peer_with_deadline_drops_frame_and_continues() {
    let port = free_port();
    let server = start_server("hang", "op-hang", port, None, "hang");
    std::thread::sleep(Duration::from_millis(200));
    let proxy = FaultProxy::start(&format!("127.0.0.1:{port}")).unwrap();
    proxy.set(Fault::BlackHole);

    let client = QueryClient::tcp("op-hang", proxy.addr())
        .with_timeout(Duration::from_millis(200))
        .with_resilience(ResilienceConfig {
            retry: 3,
            backoff: Duration::from_millis(30),
            deadline: Some(Duration::from_millis(450)),
            // Keep the breaker out of the picture: this test is about
            // leaky deadline semantics only.
            breaker: BreakerConfig { failure_threshold: 100, ..Default::default() },
            ..Default::default()
        });
    let (cr, h, rx) = client_pipeline("qc_hang", client);

    // Frame 1 is black-holed: every attempt times out, the deadline
    // expires, and the frame is DROPPED — the pipeline must not error.
    h.push(Buffer::new(vec![9, 9, 9, 9])).unwrap();
    std::thread::sleep(Duration::from_millis(700));
    assert_eq!(counter("qc_hang", "frames_dropped"), 1, "frame 1 should be dropped");

    // Heal the path: frame 2 flows normally on the same pipeline.
    proxy.set(Fault::Pass);
    h.push(Buffer::new(vec![1, 2, 3, 4])).unwrap();
    let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(&out.data[..], &[2, 4, 6, 8], "pipeline must survive the drop");
    assert!(rx.try_recv().is_err(), "dropped frame must not be delivered late");

    drop(h);
    let _ = cr.stop(Duration::from_secs(5));
    let _ = server.stop(Duration::from_secs(5));
}

// ---------------------------------------------------------------------------
// Seq stability: the retransmit of a frame carries the SAME seq
// ---------------------------------------------------------------------------

#[test]
fn retry_reuses_frame_seq() {
    // Hand-rolled server: connection 1 reads the request and dies without
    // answering; connection 2 reads the retransmit and echoes it back.
    // The two observed seqs must be identical (the old client bumped seq
    // again on retry, defeating server-side dedup).
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    let (stx, srx) = std::sync::mpsc::channel::<Option<u64>>();
    std::thread::spawn(move || {
        let (mut c1, _) = l.accept().unwrap();
        let f = wire::read_frame(&mut c1).unwrap();
        let (b1, _) = wire::decode_shared(&f).unwrap();
        stx.send(b1.meta.seq).unwrap();
        drop(c1); // die mid-exchange

        let (mut c2, _) = l.accept().unwrap();
        let f = wire::read_frame(&mut c2).unwrap();
        let (b2, caps) = wire::decode_shared(&f).unwrap();
        stx.send(b2.meta.seq).unwrap();
        let out = wire::encode(&b2, caps.as_ref(), Codec::None).unwrap();
        wire::write_frame(&mut c2, &out).unwrap();
        std::thread::sleep(Duration::from_millis(500)); // let the client read it
    });

    let client = QueryClient::tcp("op-seq", &addr)
        .with_timeout(Duration::from_secs(2))
        .with_resilience(ResilienceConfig {
            backoff: Duration::from_millis(20),
            ..Default::default()
        });
    let (cr, h, rx) = client_pipeline("qc_seq", client);
    h.push(Buffer::new(vec![7, 7, 7, 7])).unwrap();
    let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(&out.data[..], &[7, 7, 7, 7]); // echo server: no transform

    let seq1 = srx.recv_timeout(Duration::from_secs(1)).unwrap();
    let seq2 = srx.recv_timeout(Duration::from_secs(1)).unwrap();
    assert!(seq1.is_some(), "request must carry a seq");
    assert_eq!(seq1, seq2, "retransmit must reuse the original frame's seq");

    drop(h);
    let _ = cr.stop(Duration::from_secs(5));
}

// ---------------------------------------------------------------------------
// Slow-loris peer: hedged request cuts the tail via the second-best peer
// ---------------------------------------------------------------------------

#[test]
fn slow_loris_peer_hedges_to_second_best() {
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let b = broker.addr().to_string();
    let p_slow = free_port();
    let p_fast = free_port();
    let s_slow = start_server("hslow", "op-hedge", p_slow, None, "slow");
    let s_fast = start_server("hfast", "op-hedge", p_fast, None, "fast");
    std::thread::sleep(Duration::from_millis(200));

    // The slow peer sits behind a delaying proxy; both are advertised
    // manually so the ads point at the proxy, not the server itself.
    let proxy = FaultProxy::start(&format!("127.0.0.1:{p_slow}")).unwrap();
    proxy.set(Fault::Delay(Duration::from_millis(60)));
    let proxy_port: u16 = proxy.addr().rsplit(':').next().unwrap().parse().unwrap();
    let ad_slow = ServiceAd {
        operation: "op-hedge".into(),
        server_id: "slow".into(),
        host: "127.0.0.1".into(),
        port: proxy_port,
        model: "m".into(),
        load: 0.0, // idle -> preferred primary
    };
    let ad_fast = ServiceAd {
        operation: "op-hedge".into(),
        server_id: "fast".into(),
        host: "127.0.0.1".into(),
        port: p_fast,
        model: "m".into(),
        load: 0.5, // busier -> second-best, hedge target
    };
    let mc1 = MqttClient::connect(&b, discovery::server_client_options("slow", &ad_slow)).unwrap();
    discovery::advertise(&mc1, &ad_slow).unwrap();
    let mc2 = MqttClient::connect(&b, discovery::server_client_options("fast", &ad_fast)).unwrap();
    discovery::advertise(&mc2, &ad_fast).unwrap();
    std::thread::sleep(Duration::from_millis(300));

    let client = QueryClient::hybrid("op-hedge", &b)
        .unwrap()
        .with_timeout(Duration::from_secs(2))
        .with_resilience(ResilienceConfig {
            hedge_pct: Some(0.5),
            ..Default::default()
        });
    let (cr, h, rx) = client_pipeline("qc_hedge", client);

    // Warm the primary's RTT profile past MIN_RTT_SAMPLES (8).
    for i in 0..10u8 {
        h.push(Buffer::new(vec![i, i, i, i])).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(out.data[0], i.wrapping_mul(2));
    }

    // Now hang the primary completely: only a hedge to `fast` can answer.
    proxy.set(Fault::BlackHole);
    let t0 = Instant::now();
    h.push(Buffer::new(vec![21, 0, 0, 21])).unwrap();
    let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(&out.data[..], &[42, 0, 0, 42]);
    assert!(
        t0.elapsed() < Duration::from_millis(1500),
        "hedge should beat the 2s primary timeout, took {:?}",
        t0.elapsed()
    );
    assert!(counter("qc_hedge", "hedges") >= 1, "hedge must fire");
    assert!(counter("qc_hedge", "hedge_wins") >= 1, "hedge must win");

    drop(h);
    let _ = cr.stop(Duration::from_secs(5));
    let _ = s_slow.stop(Duration::from_secs(5));
    let _ = s_fast.stop(Duration::from_secs(5));
}

// ---------------------------------------------------------------------------
// Rebirth: a server that crashes and re-advertises under the same id is
// usable again (the old append-only blacklist kept it banned forever)
// ---------------------------------------------------------------------------

#[test]
fn restarted_server_with_same_id_is_reselected() {
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let b = broker.addr().to_string();
    let p1 = free_port();
    let s1 = start_server("rb1", "op-rebirth", p1, Some(&b), "reborn");
    std::thread::sleep(Duration::from_millis(400));

    let client = QueryClient::hybrid("op-rebirth", &b)
        .unwrap()
        .with_timeout(Duration::from_secs(1))
        .with_resilience(ResilienceConfig {
            retry: 4,
            backoff: Duration::from_millis(50),
            ..Default::default()
        });
    let (cr, h, rx) = client_pipeline("qc_rebirth", client);
    h.push(Buffer::new(vec![1, 0, 0, 1])).unwrap();
    assert_eq!(&rx.recv_timeout(Duration::from_secs(5)).unwrap().data[..], &[2, 0, 0, 2]);

    // Kill the server, then resurrect it: same server_id, NEW port — the
    // fresh ad must both un-ban the id and carry the new endpoint.
    let _ = s1.stop(Duration::from_secs(5));
    std::thread::sleep(Duration::from_millis(300));
    let p2 = free_port();
    let s2 = start_server("rb2", "op-rebirth", p2, Some(&b), "reborn");
    std::thread::sleep(Duration::from_millis(400));

    h.push(Buffer::new(vec![2, 0, 0, 2])).unwrap();
    let out = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(&out.data[..], &[4, 0, 0, 4]);
    // The failure history for `reborn` was reset by the fresh ad.
    let hm = health::shared("op-rebirth", BreakerConfig::default());
    assert_eq!(hm.consecutive_failures("reborn"), 0);

    drop(h);
    let _ = cr.stop(Duration::from_secs(5));
    let _ = s2.stop(Duration::from_secs(5));
}
