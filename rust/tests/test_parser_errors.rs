//! Pipeline-description parser error cases: every malformed description
//! must fail with a targeted parse/pipeline error, never a panic or a
//! silently-wrong graph.

use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::pipeline::parser;

fn parse(desc: &str) -> Result<edgepipe::pipeline::Pipeline, edgepipe::util::Error> {
    parser::parse(desc, &Registry::with_builtins(), &PipelineEnv::default())
}

fn err(desc: &str) -> String {
    match parse(desc) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("`{desc}` parsed but must fail"),
    }
}

#[test]
fn dangling_link_at_end() {
    let e = err("videotestsrc !");
    assert!(e.contains("dangling"), "{e}");
}

#[test]
fn link_with_nothing_before_it() {
    let e = err("! fakesink");
    assert!(e.contains("nothing to link from"), "{e}");
}

#[test]
fn duplicate_element_names() {
    let e = err("identity name=x ! identity name=x ! fakesink");
    assert!(e.contains("duplicate") && e.contains("x"), "{e}");
}

#[test]
fn unknown_element_kind() {
    let e = err("videotestsrc ! framepolisher ! fakesink");
    assert!(e.contains("unknown element") && e.contains("framepolisher"), "{e}");
}

#[test]
fn unknown_name_reference() {
    let e = err("videotestsrc ! fakesink nosuch. ! fakesink");
    assert!(e.contains("unknown element") && e.contains("nosuch"), "{e}");
}

#[test]
fn malformed_leaky_value() {
    let e = err("videotestsrc ! queue leaky=9 ! fakesink");
    assert!(e.contains("leaky") && e.contains("9"), "{e}");
    let e = err("videotestsrc ! queue leaky=sideways ! fakesink");
    assert!(e.contains("sideways"), "{e}");
}

#[test]
fn malformed_numeric_property() {
    let e = err("videotestsrc ! queue max-size-buffers=abc ! fakesink");
    assert!(e.contains("max-size-buffers"), "{e}");
}

#[test]
fn stray_property_without_element() {
    let e = err("leaky=2 videotestsrc ! fakesink");
    assert!(e.contains("stray property"), "{e}");
}

#[test]
fn unterminated_quote() {
    let e = err(r#"videotestsrc ! capsfilter caps="video/x-raw ! fakesink"#);
    assert!(e.contains("unterminated quote"), "{e}");
}

#[test]
fn missing_required_property() {
    let e = err("videotestsrc ! videoscale ! fakesink");
    assert!(e.contains("width"), "{e}");
    let e = err("mqttsink");
    assert!(e.contains("pub-topic"), "{e}");
}

#[test]
fn sink_pad_double_link_rejected() {
    // Two chains ending on the same named sink pad (forward reference).
    let e = err("videotestsrc ! k.sink_0 videotestsrc ! k.sink_0 fakesink name=k");
    assert!(e.contains("already linked"), "{e}");
}

#[test]
fn sink_ref_without_link() {
    let e = err("videotestsrc ! fakesink mix.sink_0");
    assert!(e.contains("without preceding"), "{e}");
}

#[test]
fn pad_growth_beyond_fixed_elements() {
    // identity has exactly one sink pad and cannot grow request pads.
    let e = err("videotestsrc ! id.sink_3 identity name=id");
    assert!(e.contains("cannot grow"), "{e}");
}

#[test]
fn tensor_filter_zero_batch_rejected() {
    let e = err("videotestsrc ! tensor_filter framework=passthrough batch=0 ! fakesink");
    assert!(e.contains("batch=0") && e.contains(">= 1"), "{e}");
}

#[test]
fn tensor_filter_zero_batch_timeout_rejected() {
    let e = err(
        "videotestsrc ! tensor_filter framework=passthrough batch=8 batch-timeout-ms=0 ! fakesink",
    );
    assert!(e.contains("batch-timeout-ms=0") && e.contains(">= 1"), "{e}");
}

#[test]
fn tensor_filter_non_numeric_batch_props_rejected() {
    let e = err("videotestsrc ! tensor_filter framework=passthrough batch=many ! fakesink");
    assert!(e.contains("batch=many") && e.contains("integer"), "{e}");
    let e = err(
        "videotestsrc ! tensor_filter framework=passthrough batch=8 batch-timeout-ms=now ! fakesink",
    );
    assert!(e.contains("batch-timeout-ms=now") && e.contains("integer"), "{e}");
}

#[test]
fn tensor_filter_timeout_without_batch_rejected() {
    let e = err("videotestsrc ! tensor_filter framework=passthrough batch-timeout-ms=5 ! fakesink");
    assert!(e.contains("without batch="), "{e}");
}

#[test]
fn tensor_filter_batched_description_parses() {
    let p = parse(
        "videotestsrc num-buffers=2 ! tensor_filter framework=passthrough batch=4 batch-timeout-ms=2 ! fakesink",
    )
    .unwrap();
    assert_eq!(p.n_nodes(), 3);
}

#[test]
fn valid_description_still_parses() {
    // Guard against over-tightening: the paper-style happy path works.
    let p = parse(
        "videotestsrc width=4 height=4 num-buffers=2 ! queue leaky=2 max-size-buffers=4 ! videoconvert ! fakesink",
    )
    .unwrap();
    assert_eq!(p.n_nodes(), 4);
}
