//! Integration: gst-launch-style descriptions parse and RUN end-to-end
//! through the registry, including the paper's listing syntax.

use std::time::Duration;

use edgepipe::element::registry::{PipelineEnv, Registry};
use edgepipe::elements::{appsink_channel, appsrc_channel};
use edgepipe::metrics;
use edgepipe::pipeline::{parser, WaitOutcome};

fn run_desc(desc: &str, secs: u64) -> WaitOutcome {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    let p = parser::parse(desc, &registry, &env).expect("parse");
    let running = p.start().expect("start");
    if secs > 0 {
        running.run_for(Duration::from_secs(secs))
    } else {
        running.wait_eos(Duration::from_secs(60))
    }
}

#[test]
fn simple_chain_to_fakesink() {
    let out = run_desc(
        "videotestsrc width=32 height=24 num-buffers=20 is-live=false ! videoconvert ! fakesink",
        0,
    );
    assert_eq!(out, WaitOutcome::Eos);
}

#[test]
fn video_to_tensor_chain() {
    metrics::global().reset();
    let out = run_desc(
        "videotestsrc width=16 height=16 num-buffers=10 is-live=false ! tensor_converter ! \
         tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 ! \
         appsink name=out",
        0,
    );
    assert_eq!(out, WaitOutcome::Eos);
    let c = metrics::global().counter("appsink.out");
    assert_eq!(c.count(), 10);
    // 16*16*3 f32 = 3072 bytes per frame
    assert_eq!(c.bytes(), 10 * 16 * 16 * 3 * 4);
}

#[test]
fn tee_branches_with_named_ref() {
    metrics::global().reset();
    let out = run_desc(
        "videotestsrc width=8 height=8 num-buffers=5 is-live=false ! tee name=ts \
         ts. ! queue ! appsink name=a \
         ts. ! queue leaky=2 ! appsink name=b",
        0,
    );
    assert_eq!(out, WaitOutcome::Eos);
    assert_eq!(metrics::global().counter("appsink.a").count(), 5);
    assert!(metrics::global().counter("appsink.b").count() >= 1);
}

#[test]
fn paper_style_implicit_link_after_padref() {
    // Listing 1 writes `ts. videoconvert ! ...` without `!` after `ts.`
    let out = run_desc(
        "videotestsrc width=8 height=8 num-buffers=3 is-live=false ! tee name=ts \
         ts. videoconvert ! fakesink",
        0,
    );
    assert_eq!(out, WaitOutcome::Eos);
}

#[test]
fn caps_filter_in_chain() {
    let out = run_desc(
        "videotestsrc width=300 height=300 num-buffers=3 is-live=false ! videoconvert ! \
         video/x-raw,width=300,height=300,format=RGB ! tensor_converter ! fakesink",
        0,
    );
    assert_eq!(out, WaitOutcome::Eos);
}

#[test]
fn caps_mismatch_fails_at_runtime() {
    let out = run_desc(
        "videotestsrc width=100 height=100 num-buffers=3 is-live=false ! \
         video/x-raw,width=300 ! fakesink",
        0,
    );
    assert!(matches!(out, WaitOutcome::Error { .. }), "got {out:?}");
}

#[test]
fn videoscale_and_transform_listing1_prefix() {
    // The Listing 1 client-side preprocessing chain (videoscale sized by
    // props; see DESIGN.md substitutions).
    let out = run_desc(
        "videotestsrc width=640 height=480 num-buffers=4 is-live=false pattern=ball ! \
         videoconvert ! videoscale width=300 height=300 ! \
         video/x-raw,width=300,height=300,format=RGB ! \
         queue leaky=2 ! tensor_converter ! \
         tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 ! \
         appsink name=l1",
        0,
    );
    assert_eq!(out, WaitOutcome::Eos);
    assert_eq!(metrics::global().counter("appsink.l1").count(), 4);
}

#[test]
fn mux_demux_roundtrip_via_description() {
    metrics::global().reset();
    let out = run_desc(
        "videotestsrc width=4 height=4 num-buffers=6 is-live=false ! tensor_converter ! tee name=t \
         t. ! queue ! mux.sink_0 \
         t. ! queue ! mux.sink_1 \
         tensor_mux name=mux ! tensor_demux name=d srcs=2 \
         d.src_0 ! appsink name=d0 \
         d.src_1 ! appsink name=d1",
        0,
    );
    assert_eq!(out, WaitOutcome::Eos);
    assert_eq!(metrics::global().counter("appsink.d0").count(), 6);
    assert_eq!(metrics::global().counter("appsink.d1").count(), 6);
}

#[test]
fn compositor_description_with_pad_props() {
    let out = run_desc(
        "videotestsrc width=8 height=8 num-buffers=5 is-live=false ! \
         compositor name=mix sink_0::zorder=1 sink_1::xpos=8 sink_1::zorder=0 ! fakesink \
         videotestsrc width=8 height=8 num-buffers=5 is-live=false pattern=ball ! mix.sink_1",
        0,
    );
    assert_eq!(out, WaitOutcome::Eos);
}

#[test]
fn appsrc_appsink_named_channels_via_description() {
    let h = appsrc_channel("pin", 8);
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    let p = parser::parse("appsrc channel=pin ! identity ! appsink channel=pout", &registry, &env)
        .unwrap();
    let rx = appsink_channel("pout").unwrap();
    let running = p.start().unwrap();
    h.push(edgepipe::buffer::Buffer::new(vec![42])).unwrap();
    assert_eq!(&rx.recv_timeout(Duration::from_secs(2)).unwrap().data[..], &[42]);
    drop(h);
    assert_eq!(running.wait_eos(Duration::from_secs(10)), WaitOutcome::Eos);
}

#[test]
fn sparse_roundtrip_via_description() {
    metrics::global().reset();
    let out = run_desc(
        "videotestsrc width=4 height=4 num-buffers=3 is-live=false ! tensor_converter ! \
         tensor_sparse_enc ! tensor_sparse_dec ! appsink name=sp",
        0,
    );
    assert_eq!(out, WaitOutcome::Eos);
    assert_eq!(metrics::global().counter("appsink.sp").count(), 3);
    assert_eq!(metrics::global().counter("appsink.sp").bytes(), 3 * 4 * 4 * 3);
}

#[test]
fn parse_errors_are_reported() {
    let registry = Registry::with_builtins();
    let env = PipelineEnv::default();
    for bad in [
        "",
        "! fakesink",
        "nonexistent_element ! fakesink",
        "videotestsrc !",
        "videotestsrc ! unknown.sink_0",
        "fakesink extra=1 ! fakesink", // fakesink has no src pad
    ] {
        assert!(
            parser::parse(bad, &registry, &env).and_then(|p| p.start().map(|_| ())).is_err(),
            "`{bad}` should fail"
        );
    }
}

#[test]
fn segment_count_for_listing2_scale() {
    // The §5.2 claim: an among-device app within 100 "lines" of pipeline
    // description. Count the Listing-2-equivalent description.
    let device_c = "videotestsrc width=640 height=480 ! tensor_converter ! \
                    tensor_decoder mode=flexbuf ! mqttsink pub-topic=camleft";
    let n = parser::segment_count(device_c);
    assert!(n > 0 && n < 100);
}
