//! Property-based tests on coordinator/substrate invariants (testkit —
//! the in-repo proptest analog).

use edgepipe::caps::Caps;
use edgepipe::mqtt::topic;
use edgepipe::serial::flexbuf::{self, Value};
use edgepipe::serial::{compress, wire, Codec};
use edgepipe::tensor::{self, sparse, DType, TensorInfo, TensorsInfo};
use edgepipe::testkit;

fn gen_dims(g: &mut testkit::Gen) -> Vec<u32> {
    let rank = g.usize(1, 4);
    (0..rank).map(|_| g.u32(1, 12)).collect()
}

fn gen_info(g: &mut testkit::Gen) -> TensorInfo {
    let dtypes = [DType::U8, DType::I16, DType::F32, DType::F64];
    TensorInfo::new(*g.choose(&dtypes), &gen_dims(g)).unwrap()
}

#[test]
fn prop_flexible_frame_roundtrip() {
    testkit::check(150, |g| {
        let n = g.usize(1, 5);
        let parts: Vec<(TensorInfo, Vec<u8>)> = (0..n)
            .map(|_| {
                let info = gen_info(g);
                let mut payload = vec![0u8; info.size()];
                for b in payload.iter_mut() {
                    *b = g.u32(0, 255) as u8;
                }
                (info, payload)
            })
            .collect();
        let refs: Vec<(TensorInfo, &[u8])> =
            parts.iter().map(|(i, p)| (i.clone(), p.as_slice())).collect();
        let frame = tensor::encode_flexible(&refs).unwrap();
        let dec = tensor::decode_flexible(&frame).unwrap();
        assert_eq!(dec.info.len(), n);
        for (i, (info, payload)) in parts.iter().enumerate() {
            assert_eq!(dec.info.tensors[i].dims, info.dims);
            assert_eq!(&frame[dec.ranges[i].clone()], payload.as_slice());
        }
    });
}

#[test]
fn prop_sparse_roundtrip_any_density() {
    testkit::check(150, |g| {
        let info = TensorInfo::new(DType::F32, &gen_dims(g)).unwrap();
        let density = g.f32_unit();
        let vals: Vec<f32> = (0..info.count())
            .map(|_| if g.bool(density) { g.f32() } else { 0.0 })
            .collect();
        let dense = tensor::f32_to_bytes(&vals);
        let enc = sparse::encode(&info, &dense).unwrap();
        let (info2, dense2) = sparse::decode(&enc).unwrap();
        assert_eq!(info2.dims, info.dims);
        assert_eq!(dense2, dense);
    });
}

#[test]
fn prop_flexbuf_value_roundtrip() {
    fn gen_value(g: &mut testkit::Gen, depth: usize) -> Value {
        match g.usize(0, if depth > 3 { 5 } else { 7 }) {
            0 => Value::Null,
            1 => Value::Bool(g.bool(0.5)),
            2 => Value::Int(g.i64()),
            3 => Value::UInt(g.u64(0, u64::MAX - 1)),
            4 => Value::Str(g.ascii_string(24)),
            5 => Value::Blob(g.vec_u8(64)),
            6 => {
                let n = g.usize(0, 4);
                Value::Vector((0..n).map(|_| gen_value(g, depth + 1)).collect())
            }
            _ => {
                let n = g.usize(0, 4);
                Value::Map(
                    (0..n).map(|i| (format!("k{i}-{}", g.ascii_string(4)), gen_value(g, depth + 1))).collect(),
                )
            }
        }
    }
    testkit::check(200, |g| {
        let v = gen_value(g, 0);
        let enc = flexbuf::encode(&v);
        assert_eq!(flexbuf::decode(&enc).unwrap(), v);
    });
}

#[test]
fn prop_flexbuf_decoder_never_panics_on_garbage() {
    testkit::check(300, |g| {
        let garbage = g.vec_u8(256);
        let _ = flexbuf::decode(&garbage); // must return, never panic/OOM
    });
}

#[test]
fn prop_bytes_slice_matches_vec_slicing() {
    use edgepipe::buffer::Bytes;
    testkit::check(200, |g| {
        let data = g.vec_u8(512);
        let b = Bytes::from(data.clone());
        // Random nested slicing must agree with plain Vec slicing and
        // always share the original backing allocation.
        let mut view = b.slice(..);
        let mut lo = 0usize;
        let mut hi = data.len();
        for _ in 0..g.usize(1, 6) {
            let len = hi - lo;
            let a = g.usize(0, len);
            let z = g.usize(a, len);
            view = view.slice(a..z);
            lo += a;
            hi = lo + (z - a);
            assert_eq!(&view[..], &data[lo..hi]);
            assert_eq!(view.len(), hi - lo);
            assert!(view.same_backing(&b));
        }
    });
}

#[test]
fn prop_bytes_wire_roundtrip_preserves_payload_views() {
    use edgepipe::buffer::{Buffer, Bytes};
    testkit::check(100, |g| {
        let payload = g.vec_u8(1024);
        let b = Buffer::new(payload.clone());
        let frame =
            Bytes::from(wire::encode(&b, None, Codec::None).unwrap());
        let (b2, _) = wire::decode_shared(&frame).unwrap();
        assert_eq!(&b2.data[..], payload.as_slice());
        assert!(b2.data.same_backing(&frame), "decode_shared must not copy");
        // Slicing the decoded view keeps both content and backing.
        if !b2.data.is_empty() {
            let cut = g.usize(0, b2.data.len() - 1);
            let tail = b2.data.slice(cut..);
            assert_eq!(&tail[..], &payload[cut..]);
            assert!(tail.same_backing(&frame));
        }
    });
}

#[test]
fn prop_wire_frame_roundtrip() {
    testkit::check(150, |g| {
        let mut b = edgepipe::buffer::Buffer::new(g.vec_u8(2048));
        if g.bool(0.7) {
            b.pts = Some(g.u64(0, 1 << 60));
        }
        if g.bool(0.5) {
            b.meta.client_id = Some(g.u64(0, 1 << 30));
            b.meta.seq = Some(g.u64(0, 1 << 30));
        }
        if g.bool(0.5) {
            b.meta.remote_base_universal = Some(g.u64(0, 1 << 62));
        }
        let codec = if g.bool(0.5) { Codec::Zlib } else { Codec::None };
        let caps = if g.bool(0.6) { Some(Caps::video(g.u32(1, 64), g.u32(1, 64), 30)) } else { None };
        let frame = wire::encode(&b, caps.as_ref(), codec).unwrap();
        let (b2, c2) = wire::decode(&frame).unwrap();
        assert_eq!(b2, b);
        assert_eq!(c2, caps);
    });
}

#[test]
fn prop_wire_decoder_never_panics_on_garbage() {
    testkit::check(300, |g| {
        let garbage = g.vec_u8(512);
        let _ = wire::decode(&garbage);
    });
}

#[test]
fn prop_compression_roundtrip() {
    testkit::check(100, |g| {
        let data = g.vec_u8(4096);
        let c = compress::compress(Codec::Zlib, &data).unwrap();
        assert_eq!(compress::decompress(Codec::Zlib, &c).unwrap(), data);
    });
}

#[test]
fn prop_caps_display_parse_roundtrip() {
    testkit::check(150, |g| {
        let mut info = TensorsInfo::default();
        for _ in 0..g.usize(1, 6) {
            info.push(gen_info(g)).unwrap();
        }
        let caps = Caps::tensors(&info);
        let parsed = Caps::parse(&caps.to_string()).unwrap();
        assert_eq!(parsed, caps);
        assert_eq!(parsed.tensors_info().unwrap(), info);
    });
}

#[test]
fn prop_topic_filter_matching_invariants() {
    testkit::check(300, |g| {
        let levels = g.usize(1, 5);
        let topic: Vec<String> = (0..levels).map(|_| g.ascii_string(6)).collect();
        let topic_str = topic.join("/");
        if topic::validate_name(&topic_str).is_err() {
            return; // empty level strings are fine to skip
        }
        // 1. A topic always matches itself as a filter.
        assert!(topic::matches(&topic_str, &topic_str));
        // 2. '#' matches everything.
        assert!(topic::matches("#", &topic_str));
        // 3. Replacing any one level with '+' still matches.
        for i in 0..levels {
            let mut f = topic.clone();
            f[i] = "+".into();
            assert!(topic::matches(&f.join("/"), &topic_str));
        }
        // 4. Truncating to a prefix + '/#' matches.
        for i in 1..=levels {
            let f = format!("{}/#", topic[..i].join("/"));
            assert!(topic::matches(&f, &topic_str));
        }
        // 5. A different first level never matches without wildcards.
        let mut other = topic.clone();
        other[0] = format!("x{}", other[0]);
        assert!(!topic::matches(&other.join("/"), &topic_str));
    });
}

#[test]
fn prop_tensors_flexbuf_roundtrip() {
    testkit::check(100, |g| {
        let mut info = TensorsInfo::default();
        let n = g.usize(1, 4);
        for _ in 0..n {
            info.push(gen_info(g)).unwrap();
        }
        let mut payload = vec![0u8; info.frame_size()];
        for b in payload.iter_mut() {
            *b = g.u32(0, 255) as u8;
        }
        let enc = edgepipe::serial::tensors_to_flexbuf(&info, &payload).unwrap();
        let (info2, payload2) = edgepipe::serial::flexbuf_to_tensors(&enc).unwrap();
        assert_eq!(info2, info);
        assert_eq!(payload2, payload);
    });
}

#[test]
fn prop_leaky_queue_never_exceeds_capacity_and_keeps_order() {
    use edgepipe::element::{Inbox, Item, Leaky, QueueCfg};
    testkit::check(80, |g| {
        let cap = g.usize(1, 8);
        let leaky = *g.choose(&[Leaky::Upstream, Leaky::Downstream]);
        let ib = Inbox::new(vec![QueueCfg { capacity: cap, leaky }]);
        let n = g.usize(0, 40);
        for i in 0..n {
            ib.push(0, Item::Buffer(edgepipe::buffer::Buffer::new(vec![i as u8]))).unwrap();
            assert!(ib.depth(0) <= cap);
        }
        // Drain: sequence numbers must be strictly increasing (order kept).
        let mut last: Option<u8> = None;
        ib.push(0, Item::Eos).unwrap();
        while let Some((_, item)) = ib.pop_any() {
            if let Item::Buffer(b) = item {
                if let Some(l) = last {
                    assert!(b.data[0] > l, "order violated: {} after {l}", b.data[0]);
                }
                last = Some(b.data[0]);
            }
        }
    });
}

#[test]
fn prop_mux_output_size_is_sum_of_inputs() {
    use edgepipe::buffer::Buffer;
    use edgepipe::elements::basic::{AppSink, AppSrc};
    use edgepipe::elements::TensorMux;
    use edgepipe::pipeline::Pipeline;
    testkit::check(12, |g| {
        let a_len = g.usize(1, 16);
        let b_len = g.usize(1, 16);
        let ia = TensorsInfo::one(TensorInfo::new(DType::U8, &[a_len as u32]).unwrap());
        let ib = TensorsInfo::one(TensorInfo::new(DType::U8, &[b_len as u32]).unwrap());
        let mut p = Pipeline::new();
        let (sa, ha) = AppSrc::new(4, Some(Caps::tensors(&ia)));
        let (sb, hb) = AppSrc::new(4, Some(Caps::tensors(&ib)));
        let (sink, rx) = AppSink::new(4);
        let a = p.add("a", Box::new(sa)).unwrap();
        let b = p.add("b", Box::new(sb)).unwrap();
        let m = p.add("m", Box::new(TensorMux::new(2))).unwrap();
        let k = p.add("k", Box::new(sink)).unwrap();
        p.link_pads(a, 0, m, 0).unwrap();
        p.link_pads(b, 0, m, 1).unwrap();
        p.link(m, k).unwrap();
        let _r = p.start().unwrap();
        ha.push(Buffer::new(vec![1; a_len]).with_pts(1)).unwrap();
        hb.push(Buffer::new(vec![2; b_len]).with_pts(2)).unwrap();
        let out = rx.recv_timeout(std::time::Duration::from_secs(2)).unwrap();
        assert_eq!(out.len(), a_len + b_len);
    });
}
