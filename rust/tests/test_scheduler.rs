//! Worker-pool scheduler semantics: the pooled runner must match the
//! thread-per-element runner observable-for-observable — delivery counts,
//! EOS/error bus traffic, leaky-queue behavior, caps ordering — while the
//! non-blocking inbox protocol (`try_pop_any`/`try_reserve`/
//! `push_reserved`) stays bit-for-bit equivalent to the condvar paths on
//! identical input sequences.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use edgepipe::buffer::Buffer;
use edgepipe::caps::Caps;
use edgepipe::element::inbox::{Reserve, TryPop};
use edgepipe::element::sched::{QueueMode, Scheduler};
use edgepipe::element::{Ctx, Element, Inbox, Item, Leaky, QueueCfg, Workload};
use edgepipe::pipeline::{ExecMode, Pipeline, WaitOutcome};
use edgepipe::testkit;
use edgepipe::util::{Error, Result};

// ---------------------------------------------------------------------------
// Test elements (all Workload::Compute unless stated).
// ---------------------------------------------------------------------------

/// Bounded compute source: n buffers, one per produce call.
struct CountSrc {
    n: u64,
    sent: u64,
}

impl Element for CountSrc {
    fn n_sink_pads(&self) -> usize {
        0
    }
    fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
        unreachable!()
    }
    fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
        if self.sent >= self.n {
            return Ok(false);
        }
        ctx.push_buffer(Buffer::new(self.sent.to_le_bytes().to_vec()).with_pts(self.sent))?;
        self.sent += 1;
        Ok(true)
    }
}

/// Counting compute sink; also tallies caps and EOS items.
#[derive(Default)]
struct Recorder {
    buffers: Arc<AtomicU64>,
    caps: Arc<AtomicU64>,
    eos: Arc<AtomicU64>,
}

struct RecordSink {
    rec: Recorder,
}

impl Element for RecordSink {
    fn n_src_pads(&self) -> usize {
        0
    }
    fn handle(&mut self, _pad: usize, item: Item, _ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Buffer(_) => self.rec.buffers.fetch_add(1, Ordering::Relaxed),
            Item::Caps(_) => self.rec.caps.fetch_add(1, Ordering::Relaxed),
            Item::Eos => self.rec.eos.fetch_add(1, Ordering::Relaxed),
        };
        Ok(())
    }
}

/// Identity filter.
struct Pass;
impl Element for Pass {
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        if !matches!(item, Item::Eos) {
            ctx.push(0, item)?;
        }
        Ok(())
    }
}

fn chain(n: u64, stages: usize) -> (Pipeline, Recorder) {
    let mut p = Pipeline::new();
    let rec = Recorder::default();
    let sink = RecordSink {
        rec: Recorder {
            buffers: rec.buffers.clone(),
            caps: rec.caps.clone(),
            eos: rec.eos.clone(),
        },
    };
    let mut prev = p.add("src", Box::new(CountSrc { n, sent: 0 })).unwrap();
    for i in 0..stages {
        let f = p.add(&format!("pass{i}"), Box::new(Pass)).unwrap();
        p.link(prev, f).unwrap();
        prev = f;
    }
    let k = p.add("sink", Box::new(sink)).unwrap();
    p.link(prev, k).unwrap();
    (p, rec)
}

// ---------------------------------------------------------------------------
// End-to-end pool-mode pipelines.
// ---------------------------------------------------------------------------

#[test]
fn pool_linear_pipeline_delivers_all_buffers_then_eos() {
    let (p, rec) = chain(200, 3);
    let running = p.start_mode(ExecMode::Pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(10)), WaitOutcome::Eos);
    assert_eq!(rec.buffers.load(Ordering::Relaxed), 200);
}

#[test]
fn threads_mode_still_delivers_all() {
    let (p, rec) = chain(200, 3);
    let running = p.start_mode(ExecMode::Threads).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(10)), WaitOutcome::Eos);
    assert_eq!(rec.buffers.load(Ordering::Relaxed), 200);
}

#[test]
fn pool_fanout_duplicates_stream() {
    let mut p = Pipeline::new();
    let c1 = Arc::new(AtomicU64::new(0));
    let c2 = Arc::new(AtomicU64::new(0));
    let s = p.add("src", Box::new(CountSrc { n: 50, sent: 0 })).unwrap();
    let k1 = p
        .add("k1", Box::new(RecordSink { rec: Recorder { buffers: c1.clone(), ..Default::default() } }))
        .unwrap();
    let k2 = p
        .add("k2", Box::new(RecordSink { rec: Recorder { buffers: c2.clone(), ..Default::default() } }))
        .unwrap();
    p.link(s, k1).unwrap();
    p.link(s, k2).unwrap();
    let running = p.start_mode(ExecMode::Pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(10)), WaitOutcome::Eos);
    assert_eq!(c1.load(Ordering::Relaxed), 50);
    assert_eq!(c2.load(Ordering::Relaxed), 50);
}

#[test]
fn pool_error_surfaces_on_bus() {
    struct Fail;
    impl Element for Fail {
        fn n_src_pads(&self) -> usize {
            0
        }
        fn handle(&mut self, _: usize, item: Item, _: &mut Ctx) -> Result<()> {
            if item.is_buffer() {
                return Err(Error::Pipeline("boom".into()));
            }
            Ok(())
        }
    }
    let mut p = Pipeline::new();
    let s = p.add("src", Box::new(CountSrc { n: 10, sent: 0 })).unwrap();
    let k = p.add("fail", Box::new(Fail)).unwrap();
    p.link(s, k).unwrap();
    let mut running = p.start_mode(ExecMode::Pool).unwrap();
    match running.wait(Duration::from_secs(10)) {
        WaitOutcome::Error { element, message } => {
            assert_eq!(element, "fail");
            assert!(message.contains("boom"));
        }
        other => panic!("expected error, got {other:?}"),
    }
}

#[test]
fn pool_stop_interrupts_spinning_source() {
    struct Forever;
    impl Element for Forever {
        fn n_sink_pads(&self) -> usize {
            0
        }
        fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
            unreachable!()
        }
        fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
            ctx.push_buffer(Buffer::new(vec![0]))?;
            Ok(true)
        }
    }
    let mut p = Pipeline::new();
    let count = Arc::new(AtomicU64::new(0));
    let s = p.add("src", Box::new(Forever)).unwrap();
    let k = p
        .add(
            "sink",
            Box::new(RecordSink { rec: Recorder { buffers: count.clone(), ..Default::default() } }),
        )
        .unwrap();
    p.link(s, k).unwrap();
    let running = p.start_mode(ExecMode::Pool).unwrap();
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(running.stop(Duration::from_secs(10)), WaitOutcome::Eos);
    assert!(count.load(Ordering::Relaxed) > 0);
}

#[test]
fn pool_backpressure_parks_instead_of_losing() {
    // Slow sink + tiny non-leaky queue: the spinning source must park on
    // reservations; every buffer still arrives (no loss, no deadlock).
    struct SlowSink {
        count: Arc<AtomicU64>,
    }
    impl Element for SlowSink {
        fn n_src_pads(&self) -> usize {
            0
        }
        fn sink_queue_cfg(&self, _: usize) -> QueueCfg {
            QueueCfg { capacity: 1, leaky: Leaky::No }
        }
        fn handle(&mut self, _: usize, item: Item, _: &mut Ctx) -> Result<()> {
            if item.is_buffer() {
                std::thread::sleep(Duration::from_millis(1));
                self.count.fetch_add(1, Ordering::Relaxed);
            }
            Ok(())
        }
    }
    let mut p = Pipeline::new();
    let count = Arc::new(AtomicU64::new(0));
    let s = p.add("src", Box::new(CountSrc { n: 100, sent: 0 })).unwrap();
    let k = p.add("sink", Box::new(SlowSink { count: count.clone() })).unwrap();
    p.link(s, k).unwrap();
    let running = p.start_mode(ExecMode::Pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
    assert_eq!(count.load(Ordering::Relaxed), 100);
}

#[test]
fn pool_leaky_queue_drops_but_conserves() {
    // Leaky downstream queue: delivered + dropped == produced, caps/EOS
    // never among the dropped.
    struct LeakySink {
        rec: Recorder,
    }
    impl Element for LeakySink {
        fn n_src_pads(&self) -> usize {
            0
        }
        fn sink_queue_cfg(&self, _: usize) -> QueueCfg {
            QueueCfg { capacity: 2, leaky: Leaky::Downstream }
        }
        fn handle(&mut self, _: usize, item: Item, _: &mut Ctx) -> Result<()> {
            match item {
                Item::Buffer(_) => {
                    std::thread::sleep(Duration::from_millis(2));
                    self.rec.buffers.fetch_add(1, Ordering::Relaxed)
                }
                Item::Caps(_) => self.rec.caps.fetch_add(1, Ordering::Relaxed),
                Item::Eos => self.rec.eos.fetch_add(1, Ordering::Relaxed),
            };
            Ok(())
        }
    }
    struct CapsySrc {
        n: u64,
        sent: u64,
    }
    impl Element for CapsySrc {
        fn n_sink_pads(&self) -> usize {
            0
        }
        fn handle(&mut self, _: usize, _: Item, _: &mut Ctx) -> Result<()> {
            unreachable!()
        }
        fn produce(&mut self, ctx: &mut Ctx) -> Result<bool> {
            if self.sent >= self.n {
                return Ok(false);
            }
            if self.sent % 50 == 0 {
                ctx.push_caps(Caps::video(2, 2, 30))?;
            }
            ctx.push_buffer(Buffer::new(vec![self.sent as u8]))?;
            self.sent += 1;
            Ok(true)
        }
    }
    let rec = Recorder::default();
    let sink = LeakySink {
        rec: Recorder {
            buffers: rec.buffers.clone(),
            caps: rec.caps.clone(),
            eos: rec.eos.clone(),
        },
    };
    let mut p = Pipeline::new();
    let s = p.add("src", Box::new(CapsySrc { n: 500, sent: 0 })).unwrap();
    let k = p.add("sink", Box::new(sink)).unwrap();
    p.link(s, k).unwrap();
    let running = p.start_mode(ExecMode::Pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
    // Unthrottled source into a 2ms-per-buffer sink: the leak must fire…
    assert!(rec.buffers.load(Ordering::Relaxed) < 500);
    // …and every control item must survive it (10 caps, 1 EOS).
    assert_eq!(rec.caps.load(Ordering::Relaxed), 10);
    assert_eq!(rec.eos.load(Ordering::Relaxed), 1);
}

#[test]
fn pool_and_threads_mix_in_one_process() {
    // Blocking elements (AppSrc/AppSink) keep threads while the middle of
    // the pipeline runs pooled; the hybrid must roundtrip intact.
    use edgepipe::elements::{AppSink, AppSrc};
    let mut p = Pipeline::new();
    let (src, h) = AppSrc::new(8, Some(Caps::video(2, 2, 30)));
    let (sink, rx) = AppSink::new(8);
    assert_eq!(src.workload(), Workload::Blocking);
    let s = p.add("src", Box::new(src)).unwrap();
    let f1 = p.add("f1", Box::new(Pass)).unwrap();
    let f2 = p.add("f2", Box::new(Pass)).unwrap();
    let k = p.add("sink", Box::new(sink)).unwrap();
    p.link(s, f1).unwrap();
    p.link(f1, f2).unwrap();
    p.link(f2, k).unwrap();
    let running = p.start_mode(ExecMode::Pool).unwrap();
    for i in 0..20u8 {
        h.push(Buffer::new(vec![i]).with_pts(i as u64)).unwrap();
    }
    for i in 0..20u8 {
        let got = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got.data[0], i, "in-order delivery");
    }
    drop(h);
    assert_eq!(running.wait_eos(Duration::from_secs(10)), WaitOutcome::Eos);
}

#[test]
fn pool_many_pipelines_all_complete() {
    // 32 six-element pipelines on the shared pool: far fewer threads than
    // elements, every pipeline still reaches EOS with full delivery.
    let mut runnings = Vec::new();
    let mut recs = Vec::new();
    for _ in 0..32 {
        let (p, rec) = chain(100, 4);
        runnings.push(p.start_mode(ExecMode::Pool).unwrap());
        recs.push(rec);
    }
    for r in runnings {
        assert_eq!(r.wait_eos(Duration::from_secs(60)), WaitOutcome::Eos);
    }
    for rec in recs {
        assert_eq!(rec.buffers.load(Ordering::Relaxed), 100);
    }
}

#[test]
fn sched_metrics_counters_advance() {
    let tasks0 = edgepipe::metrics::global().counter("sched.tasks").count();
    let (p, _rec) = chain(50, 2);
    let running = p.start_mode(ExecMode::Pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(10)), WaitOutcome::Eos);
    let g = edgepipe::metrics::global();
    assert!(g.counter("sched.tasks").count() >= tasks0 + 4, "src + 2 pass + sink spawned");
    assert!(g.counter("sched.polls").count() > 0);
}

// ---------------------------------------------------------------------------
// Work-stealing correctness: claim CAS, wake/steal races, batch wakeups.
// ---------------------------------------------------------------------------

/// Pass-through filter that detects concurrent entry: if two workers ever
/// run the same task at once, `handle` overlaps with itself and the
/// violation counter trips.
struct GuardedPass {
    busy: Arc<std::sync::atomic::AtomicBool>,
    violations: Arc<AtomicU64>,
}

impl Element for GuardedPass {
    fn sink_queue_cfg(&self, _: usize) -> QueueCfg {
        // Capacity 1 maximises park/wake/steal churn on every link.
        QueueCfg { capacity: 1, leaky: Leaky::No }
    }
    fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<()> {
        if self.busy.swap(true, Ordering::SeqCst) {
            self.violations.fetch_add(1, Ordering::Relaxed);
        }
        let out = if !matches!(item, Item::Eos) { ctx.push(0, item) } else { Ok(()) };
        self.busy.store(false, Ordering::SeqCst);
        out
    }
}

#[test]
fn no_task_runs_on_two_workers_at_once_under_churn() {
    // 8 pipelines x 3 capacity-1 stages: thousands of park/wake/steal
    // transitions. The QUEUED->RUNNING claim CAS must keep every task on
    // at most one worker at any instant, and no wakeup may be lost (all
    // pipelines reach EOS with full delivery).
    let violations = Arc::new(AtomicU64::new(0));
    let mut runnings = Vec::new();
    let mut recs = Vec::new();
    for _ in 0..8 {
        let mut p = Pipeline::new();
        let rec = Recorder::default();
        let sink = RecordSink {
            rec: Recorder {
                buffers: rec.buffers.clone(),
                caps: rec.caps.clone(),
                eos: rec.eos.clone(),
            },
        };
        let mut prev = p.add("src", Box::new(CountSrc { n: 300, sent: 0 })).unwrap();
        for i in 0..3 {
            let g = p
                .add(
                    &format!("g{i}"),
                    Box::new(GuardedPass {
                        busy: Arc::new(std::sync::atomic::AtomicBool::new(false)),
                        violations: violations.clone(),
                    }),
                )
                .unwrap();
            p.link(prev, g).unwrap();
            prev = g;
        }
        let k = p.add("sink", Box::new(sink)).unwrap();
        p.link(prev, k).unwrap();
        runnings.push(p.start_mode(ExecMode::Pool).unwrap());
        recs.push(rec);
    }
    for r in runnings {
        assert_eq!(r.wait_eos(Duration::from_secs(60)), WaitOutcome::Eos, "lost wakeup wedged a pipeline");
    }
    for rec in recs {
        assert_eq!(rec.buffers.load(Ordering::Relaxed), 300);
    }
    assert_eq!(violations.load(Ordering::Relaxed), 0, "a task ran on two workers at once");
}

/// Fan-in collector: one element with several sink pads, each fed by its
/// own source — the batch-wakeup shape (EOS fan-out + multi-producer
/// wakes onto one consumer).
struct Collector {
    pads: usize,
    rec: Recorder,
}

impl Element for Collector {
    fn n_sink_pads(&self) -> usize {
        self.pads
    }
    fn n_src_pads(&self) -> usize {
        0
    }
    fn sink_queue_cfg(&self, _: usize) -> QueueCfg {
        QueueCfg { capacity: 2, leaky: Leaky::No }
    }
    fn handle(&mut self, _pad: usize, item: Item, _ctx: &mut Ctx) -> Result<()> {
        match item {
            Item::Buffer(_) => self.rec.buffers.fetch_add(1, Ordering::Relaxed),
            Item::Caps(_) => self.rec.caps.fetch_add(1, Ordering::Relaxed),
            Item::Eos => self.rec.eos.fetch_add(1, Ordering::Relaxed),
        };
        Ok(())
    }
}

#[test]
fn fanin_batch_wakeups_conserve_items_and_eos() {
    // 6 sources -> one 6-pad collector: every buffer and every per-pad
    // EOS must arrive exactly once even though wakes are batched per
    // turn and EOS fan-out fires its wakers in one pass.
    let rec = Recorder::default();
    let collector = Collector {
        pads: 6,
        rec: Recorder {
            buffers: rec.buffers.clone(),
            caps: rec.caps.clone(),
            eos: rec.eos.clone(),
        },
    };
    let mut p = Pipeline::new();
    let c = p.add("collect", Box::new(collector)).unwrap();
    for i in 0..6 {
        let s = p.add(&format!("src{i}"), Box::new(CountSrc { n: 100, sent: 0 })).unwrap();
        p.link_pads(s, 0, c, i).unwrap();
    }
    let running = p.start_mode(ExecMode::Pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
    assert_eq!(rec.buffers.load(Ordering::Relaxed), 600, "fan-in lost or duplicated buffers");
    assert_eq!(rec.eos.load(Ordering::Relaxed), 6, "batched EOS fan-out lost a pad");
}

#[test]
fn queue_counters_split_local_and_injector() {
    let g = edgepipe::metrics::global();
    let l0 = g.counter("sched.local_hits").count();
    let i0 = g.counter("sched.injector_hits").count();
    let (p, rec) = chain(400, 4);
    let running = p.start_mode(ExecMode::Pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
    assert_eq!(rec.buffers.load(Ordering::Relaxed), 400);
    // Spawns come from this (non-worker) thread -> injector; wakes issued
    // on worker threads land on local queues (Chase-Lev or mutex deques).
    assert!(g.counter("sched.injector_hits").count() > i0, "spawned tasks bypass the injector");
    if edgepipe::element::sched::global().queue_mode() != QueueMode::Shared {
        assert!(g.counter("sched.local_hits").count() > l0, "worker-side wakes never hit local queues");
    }
}

#[test]
fn detached_shared_queue_pool_still_delivers() {
    // The shared-queue comparator architecture must stay semantically
    // identical (it is the bench baseline).
    let pool = Scheduler::start_detached(2, QueueMode::Shared);
    assert_eq!(pool.queue_mode(), QueueMode::Shared);
    let (p, rec) = chain(150, 3);
    let running = p.start_pooled_on(&pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
    assert_eq!(rec.buffers.load(Ordering::Relaxed), 150);
}

#[test]
fn detached_mutex_stealing_pool_still_delivers() {
    // The schema-4 mutex-deque architecture stays available as the
    // second bench comparator; its delivery semantics must not drift
    // now that the global default is the Chase-Lev pool.
    let pool = Scheduler::start_detached(2, QueueMode::Stealing);
    assert_eq!(pool.queue_mode(), QueueMode::Stealing);
    let (p, rec) = chain(150, 3);
    let running = p.start_pooled_on(&pool).unwrap();
    assert_eq!(running.wait_eos(Duration::from_secs(30)), WaitOutcome::Eos);
    assert_eq!(rec.buffers.load(Ordering::Relaxed), 150);
}

#[test]
fn detached_chaselev_pool_delivers_under_parallel_churn() {
    // Many short pipelines on a small Chase-Lev pool: spawn/teardown
    // enqueues hit the batched injector drain, worker-side wakes hit the
    // lock-free deques, and idle workers batch-steal — every buffer must
    // still arrive exactly once (the claim CAS dedupes stale entries).
    let pool = Scheduler::start_detached(2, QueueMode::ChaseLev);
    assert_eq!(pool.queue_mode(), QueueMode::ChaseLev);
    let mut running = Vec::new();
    let mut recs = Vec::new();
    for _ in 0..8 {
        let (p, rec) = chain(200, 3);
        running.push(p.start_pooled_on(&pool).unwrap());
        recs.push(rec);
    }
    for r in running {
        assert_eq!(r.wait_eos(Duration::from_secs(60)), WaitOutcome::Eos);
    }
    for rec in recs {
        assert_eq!(rec.buffers.load(Ordering::Relaxed), 200);
    }
}

// ---------------------------------------------------------------------------
// Inbox-level equivalence: cooperative protocol vs condvar protocol on
// identical deterministic sequences.
// ---------------------------------------------------------------------------

fn buf(n: u8) -> Item {
    Item::Buffer(Buffer::new(vec![n]))
}

#[test]
fn prop_leaky_drop_counts_match_condvar_path() {
    // Same interleaving of pushes and pops against two inboxes — one
    // driven with push/pop_any (condvar discipline), one with
    // try_reserve+push_reserved/try_pop_any (scheduler discipline).
    // Leaky drop counts, queue depths, and popped sequences must match
    // exactly.
    testkit::check(120, |g| {
        let cap = g.usize(1, 6);
        let leaky = *g.choose(&[Leaky::Upstream, Leaky::Downstream]);
        let a = Inbox::new(vec![QueueCfg { capacity: cap, leaky }]);
        let b = Inbox::new(vec![QueueCfg { capacity: cap, leaky }]);
        let ops = g.usize(1, 60);
        let mut seq = 0u8;
        for _ in 0..ops {
            if g.bool(0.6) {
                seq = seq.wrapping_add(1);
                // Occasionally interleave caps to prove they never leak.
                if seq % 13 == 0 {
                    a.push(0, Item::Caps(Caps::any())).unwrap();
                    b.push(0, Item::Caps(Caps::any())).unwrap();
                }
                a.push(0, buf(seq)).unwrap();
                match b.try_reserve(0) {
                    Reserve::Counted => b.push_reserved(0, buf(seq)).unwrap(),
                    // Leaky pads never count; the plain push applies the
                    // identical leak policy without blocking.
                    Reserve::NoNeed => b.push(0, buf(seq)).unwrap(),
                    Reserve::Full => panic!("leaky pad reported Full"),
                }
            } else {
                let pa = a.pop_any_timeout(Duration::from_millis(0));
                let pb = b.try_pop_any();
                match (pa, pb) {
                    (Some(Some((_, Item::Buffer(x)))), TryPop::Item(_, Item::Buffer(y))) => {
                        assert_eq!(x.data[0], y.data[0], "pop order diverged");
                    }
                    (Some(Some((_, Item::Caps(_)))), TryPop::Item(_, Item::Caps(_))) => {}
                    (Some(None), TryPop::Empty) => {}
                    (x, y) => panic!("pop results diverged: {x:?} vs {y:?}"),
                }
            }
            assert_eq!(a.depth(0), b.depth(0), "depths diverged");
            assert_eq!(a.dropped(0), b.dropped(0), "drop counts diverged");
            assert!(a.depth(0) <= cap);
        }
    });
}

#[test]
fn prop_reserved_pushes_respect_capacity_and_eos() {
    // Leaky::No under the cooperative protocol: depth+reserved never
    // exceeds capacity, Full is reported exactly when no slot remains,
    // and caps/EOS enqueue regardless.
    testkit::check(120, |g| {
        let cap = g.usize(1, 5);
        let ib = Inbox::new(vec![QueueCfg { capacity: cap, leaky: Leaky::No }]);
        let mut held = 0usize;
        let ops = g.usize(1, 50);
        for _ in 0..ops {
            match g.usize(0, 3) {
                0 => match ib.try_reserve(0) {
                    Reserve::Counted => held += 1,
                    Reserve::Full => assert_eq!(ib.depth(0) + held, cap),
                    Reserve::NoNeed => panic!("Leaky::No pad reported NoNeed while open"),
                },
                1 if held > 0 => {
                    ib.push_reserved(0, buf(7)).unwrap();
                    held -= 1;
                }
                2 if held > 0 => {
                    ib.unreserve(0);
                    held -= 1;
                }
                _ => {
                    let _ = ib.try_pop_any();
                }
            }
            assert!(ib.depth(0) + held <= cap, "capacity bound violated");
            assert_eq!(ib.reserved(0), held, "reservation ledger diverged");
        }
        // Control items always land, even with every slot spoken for.
        while let Reserve::Counted = ib.try_reserve(0) {
            held += 1;
        }
        ib.push(0, Item::Caps(Caps::any())).unwrap();
        ib.push(0, Item::Eos).unwrap();
        let mut saw_caps = false;
        let mut saw_eos = false;
        loop {
            match ib.try_pop_any() {
                TryPop::Item(_, Item::Caps(_)) => saw_caps = true,
                TryPop::Item(_, Item::Eos) => saw_eos = true,
                TryPop::Item(_, _) => {}
                TryPop::Empty | TryPop::Done => break,
            }
        }
        assert!(saw_caps && saw_eos, "caps/EOS dropped under reservation pressure");
    });
}
