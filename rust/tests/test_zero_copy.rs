//! Zero-copy invariants of the among-device wire path: pointer/backing
//! assertions that tee fan-out, wire decode, tensor demux, and broker
//! fan-out never duplicate payload bytes.

use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use edgepipe::buffer::{bytes_copied, Buffer, Bytes};
use edgepipe::caps::Caps;
use edgepipe::elements::basic::{AppSink, AppSrc};
use edgepipe::elements::TensorDemux;
use edgepipe::mqtt::{Broker, ClientOptions, MqttClient};
use edgepipe::pipeline::Pipeline;
use edgepipe::serial::{wire, Codec};
use edgepipe::tensor::{DType, TensorInfo, TensorsInfo};

/// Serialise tests that measure the process-global copy counter so a
/// concurrently running test can't pollute the delta.
fn counter_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[test]
fn wire_decode_shares_the_received_frame_allocation() {
    // wire::encode's compat assembly records copies — hold the counter
    // lock so the fan-out copy-budget tests see a clean delta.
    let _guard = counter_lock();
    let buf = Buffer::new(vec![7u8; 4096]).with_pts(11);
    let frame = Bytes::from(wire::encode(&buf, Some(&Caps::video(8, 8, 30)), Codec::None).unwrap());
    let (decoded, caps) = wire::decode_shared(&frame).unwrap();
    assert_eq!(&decoded.data[..], &buf.data[..]);
    assert!(decoded.data.same_backing(&frame), "decode copied the payload");
    assert!(caps.is_some());
}

#[test]
fn wire_encode_vectored_shares_the_buffer_payload() {
    let buf = Buffer::new(vec![3u8; 100_000]);
    let wf = wire::encode_vectored(&buf, None, Codec::None).unwrap();
    assert!(wf.payload.same_backing(&buf.data), "encode copied the payload");
    assert_eq!(wf.payload.len(), 100_000);
}

#[test]
fn tee_fanout_shares_one_payload_across_sinks() {
    let info = TensorsInfo::one(TensorInfo::new(DType::U8, &[16]).unwrap());
    let mut p = Pipeline::new();
    let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
    let (k1, r1) = AppSink::new(4);
    let (k2, r2) = AppSink::new(4);
    let s = p.add("src", Box::new(src)).unwrap();
    let a = p.add("k1", Box::new(k1)).unwrap();
    let b = p.add("k2", Box::new(k2)).unwrap();
    // Implicit tee: one src pad linked to two sinks.
    p.link(s, a).unwrap();
    p.link(s, b).unwrap();
    let _r = p.start().unwrap();
    let original = Buffer::new((0..16).collect());
    let backing = original.data.clone();
    h.push(original).unwrap();
    let o1 = r1.recv_timeout(Duration::from_secs(2)).unwrap();
    let o2 = r2.recv_timeout(Duration::from_secs(2)).unwrap();
    assert!(o1.data.same_backing(&backing), "tee copied for sink 1");
    assert!(o2.data.same_backing(&backing), "tee copied for sink 2");
}

#[test]
fn demux_outputs_are_views_into_the_combined_frame() {
    let mut info = TensorsInfo::default();
    info.push(TensorInfo::new(DType::U8, &[2]).unwrap()).unwrap();
    info.push(TensorInfo::new(DType::U8, &[3]).unwrap()).unwrap();
    let mut p = Pipeline::new();
    let (src, h) = AppSrc::new(4, Some(Caps::tensors(&info)));
    let (k0, r0) = AppSink::new(4);
    let (k1, r1) = AppSink::new(4);
    let s = p.add("s", Box::new(src)).unwrap();
    let d = p.add("d", Box::new(TensorDemux::new(2))).unwrap();
    let a = p.add("k0", Box::new(k0)).unwrap();
    let b = p.add("k1", Box::new(k1)).unwrap();
    p.link(s, d).unwrap();
    p.link_pads(d, 0, a, 0).unwrap();
    p.link_pads(d, 1, b, 0).unwrap();
    let _r = p.start().unwrap();
    let combined = Buffer::new(vec![1, 2, 3, 4, 5]);
    let backing = combined.data.clone();
    h.push(combined).unwrap();
    let o0 = r0.recv_timeout(Duration::from_secs(2)).unwrap();
    let o1 = r1.recv_timeout(Duration::from_secs(2)).unwrap();
    assert_eq!(&o0.data[..], &[1, 2]);
    assert_eq!(&o1.data[..], &[3, 4, 5]);
    assert!(o0.data.same_backing(&backing), "demux copied tensor 0");
    assert!(o1.data.same_backing(&backing), "demux copied tensor 1");
}

/// Publish `frames` H-ish frames through a real broker to `n_subs`
/// subscribers and return (delivered, counted-copy delta).
fn broker_roundtrip(n_subs: usize, frames: usize, payload_len: usize) -> (u64, u64) {
    let broker = Broker::start("127.0.0.1:0").unwrap();
    let addr = broker.addr().to_string();
    let mut rxs = Vec::new();
    let mut subs = Vec::new();
    for i in 0..n_subs {
        let c = MqttClient::connect(
            &addr,
            ClientOptions { client_id: format!("zc-sub-{i}"), ..Default::default() },
        )
        .unwrap();
        rxs.push(c.subscribe("zc/topic").unwrap());
        subs.push(c);
    }
    let publ = MqttClient::connect(
        &addr,
        ClientOptions { client_id: "zc-pub".into(), ..Default::default() },
    )
    .unwrap();
    std::thread::sleep(Duration::from_millis(200));

    let buf = Buffer::new(vec![0xEEu8; payload_len]).with_pts(5);
    let caps = Caps::video(64, 64, 30);
    let copied0 = bytes_copied();
    for _ in 0..frames {
        let wf = wire::encode_vectored(&buf, Some(&caps), Codec::None).unwrap();
        publ.publish_frame("zc/topic", &wf, false).unwrap();
    }
    let mut delivered = 0u64;
    for rx in &rxs {
        for _ in 0..frames {
            let msg = rx.recv_timeout(Duration::from_secs(3)).unwrap();
            let (out, _) = wire::decode_shared(&msg.payload).unwrap();
            assert_eq!(out.len(), payload_len);
            assert!(
                out.data.same_backing(&msg.payload),
                "subscriber decode copied the payload"
            );
            delivered += 1;
        }
    }
    let copied = bytes_copied() - copied0;
    publ.disconnect();
    for c in &subs {
        c.disconnect();
    }
    (delivered, copied)
}

#[test]
fn broker_fanout_payload_copies_independent_of_subscriber_count() {
    let _guard = counter_lock();
    let payload = 64 * 64 * 3;
    let (d1, c1) = broker_roundtrip(1, 8, payload);
    let (d4, c4) = broker_roundtrip(4, 8, payload);
    assert_eq!(d1, 8);
    assert_eq!(d4, 32);
    // The whole pub/sub path is copy-free: encode shares the buffer,
    // the broker shares one encoded head+payload across subscribers, and
    // each receive is one socket allocation + slice views. Any counted
    // copies would scale with subscriber count; both must be ~zero.
    let per_frame_1 = c1 as f64 / d1 as f64 / payload as f64;
    let per_frame_4 = c4 as f64 / d4 as f64 / payload as f64;
    assert!(per_frame_1 <= 0.01, "1-sub path copied {per_frame_1:.3} payloads/frame");
    assert!(per_frame_4 <= 0.01, "4-sub path copied {per_frame_4:.3} payloads/frame");
}

#[test]
fn query_exchange_stays_under_copy_budget() {
    let _guard = counter_lock();
    // In-memory replica of one query request hop: encode -> framed write
    // -> framed read -> decode. Budget: encode 0 copies, decode 0 (the
    // read allocation is not a payload copy).
    let payload = 32 * 1024;
    let buf = Buffer::new(vec![9u8; payload]);
    let copied0 = bytes_copied();
    let wf = wire::encode_vectored(&buf, None, Codec::None).unwrap();
    let mut sock = Vec::new();
    wire::write_frame_vectored(&mut sock, &wf).unwrap();
    let mut cur = std::io::Cursor::new(&sock[..]);
    let frame = wire::read_frame(&mut cur).unwrap();
    let (out, _) = wire::decode_shared(&frame).unwrap();
    assert_eq!(&out.data[..], &buf.data[..]);
    let copied = bytes_copied() - copied0;
    assert_eq!(copied, 0, "query hop counted {copied} copied bytes");
}
