#!/usr/bin/env bash
# Run the wire-path bench suite with short CI-friendly windows and write
# BENCH_wirepath.json at the repo root (override window/runs/out via
# EDGEPIPE_BENCH_SECS / EDGEPIPE_BENCH_RUNS / EDGEPIPE_BENCH_OUT).
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"

export EDGEPIPE_BENCH_SECS="${EDGEPIPE_BENCH_SECS:-2}"
export EDGEPIPE_BENCH_RUNS="${EDGEPIPE_BENCH_RUNS:-1}"
export EDGEPIPE_BENCH_OUT="${EDGEPIPE_BENCH_OUT:-$repo_root/BENCH_wirepath.json}"

cd "$repo_root/rust"
cargo bench --bench bench_wirepath

echo "bench report: $EDGEPIPE_BENCH_OUT"
