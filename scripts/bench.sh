#!/usr/bin/env bash
# Run the gated bench suites with short CI-friendly windows and write
# BENCH_wirepath.json + BENCH_failover.json at the repo root (override
# window/runs via EDGEPIPE_BENCH_SECS / EDGEPIPE_BENCH_RUNS; output paths
# via EDGEPIPE_BENCH_OUT / EDGEPIPE_BENCH_FAILOVER_OUT).
#
# Each report is written atomically: the bench emits into a temp file and
# only a fully successful run replaces the previous report. A bench that
# fails partway (budget assertion, panic, build error) exits non-zero and
# leaves the old report untouched.
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"

export EDGEPIPE_BENCH_SECS="${EDGEPIPE_BENCH_SECS:-2}"
export EDGEPIPE_BENCH_RUNS="${EDGEPIPE_BENCH_RUNS:-1}"
# Density scenario: fixed pool size so the thread-reduction gate is
# machine-independent (the bench also defaults this itself).
export EDGEPIPE_WORKERS="${EDGEPIPE_WORKERS:-4}"
# Many-subscriber scenario (schema 6): subscription counts for the
# sharded-trie router gates. CI overrides to "1000,8000".
export EDGEPIPE_BENCH_SUBS="${EDGEPIPE_BENCH_SUBS:-1000,10000,100000}"

# Canonicalize: benches run from rust/, so a relative output path would
# otherwise resolve against a different directory than the mktemp.
canon() {
  case "$1" in
    /*) printf '%s' "$1" ;;
    *) printf '%s' "$(pwd)/$1" ;;
  esac
}

# run_bench <bench-name> <final-report-path>
run_bench() {
  local name="$1" out="$2" tmp
  tmp="$(mktemp "${out}.XXXXXX")"
  # shellcheck disable=SC2064
  trap "rm -f '$tmp'" RETURN
  if ! (cd "$repo_root/rust" && EDGEPIPE_BENCH_OUT="$tmp" cargo bench --bench "$name"); then
    echo "$name failed; previous report left untouched: $out" >&2
    return 1
  fi
  if [ ! -s "$tmp" ]; then
    echo "$name exited 0 but wrote no report; previous report left untouched: $out" >&2
    return 1
  fi
  mv "$tmp" "$out"
  echo "bench report: $out"
}

run_bench bench_wirepath "$(canon "${EDGEPIPE_BENCH_OUT:-$repo_root/BENCH_wirepath.json}")"
run_bench bench_failover "$(canon "${EDGEPIPE_BENCH_FAILOVER_OUT:-$repo_root/BENCH_failover.json}")"
