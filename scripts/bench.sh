#!/usr/bin/env bash
# Run the wire-path bench suite with short CI-friendly windows and write
# BENCH_wirepath.json at the repo root (override window/runs/out via
# EDGEPIPE_BENCH_SECS / EDGEPIPE_BENCH_RUNS / EDGEPIPE_BENCH_OUT).
#
# The report is written atomically: the bench emits into a temp file and
# only a fully successful run replaces the previous report. A bench that
# fails partway (budget assertion, panic, build error) exits non-zero and
# leaves the old BENCH_wirepath.json untouched.
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"

export EDGEPIPE_BENCH_SECS="${EDGEPIPE_BENCH_SECS:-2}"
export EDGEPIPE_BENCH_RUNS="${EDGEPIPE_BENCH_RUNS:-1}"
# Density scenario: fixed pool size so the thread-reduction gate is
# machine-independent (the bench also defaults this itself).
export EDGEPIPE_WORKERS="${EDGEPIPE_WORKERS:-4}"
out="${EDGEPIPE_BENCH_OUT:-$repo_root/BENCH_wirepath.json}"
# Canonicalize: the bench runs from rust/, so a relative EDGEPIPE_BENCH_OUT
# would otherwise resolve against a different directory than the mktemp.
case "$out" in
  /*) ;;
  *) out="$(pwd)/$out" ;;
esac

tmp="$(mktemp "${out}.XXXXXX")"
cleanup() { rm -f "$tmp"; }
trap cleanup EXIT

cd "$repo_root/rust"
if ! EDGEPIPE_BENCH_OUT="$tmp" cargo bench --bench bench_wirepath; then
  echo "bench_wirepath failed; previous report left untouched: $out" >&2
  exit 1
fi

if [ ! -s "$tmp" ]; then
  echo "bench_wirepath exited 0 but wrote no report; previous report left untouched: $out" >&2
  exit 1
fi

mv "$tmp" "$out"
trap - EXIT
echo "bench report: $out"
