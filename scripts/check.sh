#!/usr/bin/env bash
# Repo gate: formatting, lints, and the tier-1 build+test sweep.
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$repo_root/rust"

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== tier-1: cargo build --release && cargo test -q"
cargo build --release
cargo test -q
